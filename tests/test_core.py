"""Core substrate: tree, routing/counting sort, dispatch, lookup."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dispatch import combine_rows, dispatch_rows, make_dispatch
from repro.core.lookup import build_lookup
from repro.core.route import SENTINEL, counting_layout, scatter_to_slots
from repro.core.tree import build_tree, tree_assign


# ---------------------------------------------------------------------------
# counting sort / routing layout
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 300),
    n_dest=st.integers(1, 16),
    capacity=st.integers(1, 64),
    seed=st.integers(0, 2**30),
)
def test_counting_layout_properties(n, n_dest, capacity, seed):
    dest = jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, n_dest)
    lay = counting_layout(dest, n_dest, capacity)
    slot = np.array(lay.slot_of_row)
    fits = np.array(lay.fits)
    d = np.array(dest)
    # every fitting row lands in its destination's slot range, no collisions
    used = slot[fits]
    assert len(np.unique(used)) == len(used)
    assert ((used // capacity) == d[fits]).all()
    # overflow = rows beyond capacity per destination
    expect_drop = sum(
        max(0, int((d == i).sum()) - capacity) for i in range(n_dest)
    )
    assert int(lay.overflow) == expect_drop
    # stability: within a destination, earlier rows occupy earlier slots
    for i in range(n_dest):
        rows = np.flatnonzero((d == i) & fits)
        assert (np.diff(slot[rows]) > 0).all() if len(rows) > 1 else True


def test_scatter_to_slots_roundtrip():
    dest = jnp.asarray([0, 1, 0, 2, 1, 0])
    x = jnp.arange(6.0)[:, None] * jnp.ones((6, 3))
    lay = counting_layout(dest, 3, 4)
    buf = scatter_to_slots(lay, x, 3, 4)
    buf = np.array(buf).reshape(3, 4, 3)
    np.testing.assert_array_equal(buf[0, :3, 0], [0, 2, 5])
    np.testing.assert_array_equal(buf[1, :2, 0], [1, 4])
    np.testing.assert_array_equal(buf[2, :1, 0], [3])
    assert (buf[0, 3:] == 0).all() and (buf[2, 1:] == 0).all()


# ---------------------------------------------------------------------------
# dispatch / combine (the MoE + index shared substrate)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 200),
    nb=st.integers(1, 8),
    seed=st.integers(0, 2**30),
)
def test_dispatch_combine_roundtrip(n, nb, seed):
    assign = jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, nb)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, 5))
    capacity = n  # ample: nothing dropped
    d = make_dispatch(assign, nb, capacity)
    assert int(d.overflow) == 0
    buckets = dispatch_rows(d, x)
    back = combine_rows(d, buckets)
    np.testing.assert_allclose(np.array(back), np.array(x), rtol=1e-6)


def test_dispatch_drops_are_counted_and_zero_filled():
    assign = jnp.zeros((10,), jnp.int32)  # all to bucket 0
    x = jnp.ones((10, 2))
    d = make_dispatch(assign, 2, capacity=4)
    assert int(d.overflow) == 6
    back = np.array(combine_rows(d, dispatch_rows(d, x)))
    assert (back[:4] == 1).all() and (back[4:] == 0).all()


# ---------------------------------------------------------------------------
# tree
# ---------------------------------------------------------------------------


def test_tree_build_shapes_and_determinism():
    vecs = jax.random.normal(jax.random.PRNGKey(0), (2000, 16)) * 3
    t1 = build_tree(vecs, (4, 8), key=jax.random.PRNGKey(7))
    t2 = build_tree(vecs, (4, 8), key=jax.random.PRNGKey(7))
    assert t1.fanouts == (4, 8) and t1.n_leaves == 32
    assert t1.levels[0].shape == (4, 16)
    assert t1.levels[1].shape == (4, 8, 16)
    for a, b in zip(t1.levels, t2.levels):
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_tree_assign_matches_manual_traversal():
    vecs = jax.random.normal(jax.random.PRNGKey(1), (500, 8))
    tree = build_tree(vecs, (4, 4), key=jax.random.PRNGKey(2))
    leaves = np.array(tree_assign(tree, vecs))
    l0 = np.array(tree.levels[0])
    l1 = np.array(tree.levels[1])
    V = np.array(vecs)
    for i in range(0, 500, 37):
        b = ((V[i] - l0) ** 2).sum(1).argmin()
        c = ((V[i] - l1[b]) ** 2).sum(1).argmin()
        assert leaves[i] == b * 4 + c
    assert leaves.min() >= 0 and leaves.max() < tree.n_leaves


def test_tree_refinement_reduces_quantization_error():
    vecs = jax.random.normal(jax.random.PRNGKey(3), (4000, 8)) * 2
    t0 = build_tree(vecs, (8, 4), key=jax.random.PRNGKey(4), refine_iters=0)
    t2 = build_tree(vecs, (8, 4), key=jax.random.PRNGKey(4), refine_iters=2)

    def qerr(tree):
        leaves = tree_assign(tree, vecs)
        flat = tree.levels[1].reshape(-1, 8)
        return float(jnp.mean(jnp.sum((vecs - flat[leaves]) ** 2, -1)))

    assert qerr(t2) < qerr(t0)


# ---------------------------------------------------------------------------
# lookup table
# ---------------------------------------------------------------------------


def test_lookup_table_csr_invariants():
    vecs = jax.random.normal(jax.random.PRNGKey(5), (800, 8))
    tree = build_tree(vecs, (4, 4), key=jax.random.PRNGKey(6))
    queries = jax.random.normal(jax.random.PRNGKey(7), (100, 8))
    lk = jax.jit(build_lookup)(tree, queries)
    leaves = np.array(lk.leaves)
    offs = np.array(lk.offsets)
    assert (np.diff(leaves) >= 0).all(), "queries must be leaf-sorted"
    assert offs[0] == 0 and offs[-1] == 100
    assert (np.diff(offs) >= 0).all()
    # CSR slices select exactly the queries of each leaf
    for leaf in np.unique(leaves):
        s, e = offs[leaf], offs[leaf + 1]
        assert (leaves[s:e] == leaf).all()
    # permutation round-trips
    orig = np.array(tree_assign(tree, queries))
    np.testing.assert_array_equal(orig[np.array(lk.qids)], leaves)
