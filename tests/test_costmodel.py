"""The pluggable cost-model subsystem: calibration store round-trips,
model selection/fallback (fitted > observed > heuristic), fitted-model
generalization + monotonicity, manifest-persisted index-scoped
calibration, and the bit-identity invariant — the model picks plans,
never results — under every cost-model setting."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.engine import costmodel as costmodel_lib
from repro.core.engine import (
    SearchPlan,
    CalibrationStore,
    FittedModel,
    HeuristicModel,
    ObservedModel,
    PlanShapes,
    default_calibration,
    fitted_component,
    plan as make_plan,
    plan_signature,
    resolve_model,
    scale_slab_budget,
    shard_slab_scales,
)
from repro.core.index_build import build_index
from repro.core.tree import build_tree
from repro.data import synth
from repro.distributed.meshutil import local_mesh
from repro.index import Index, ShardedIndex

SHAPES = dict(rows=65_536, n_leaves=64, n_queries=256, n_shards=1, k=10)


def _candidates(**overrides):
    kw = dict(SHAPES, **overrides)
    return (
        make_plan(layout="point_major", **kw),
        make_plan(layout="query_routed", **kw),
    )


def _ctx(**overrides):
    kw = dict(SHAPES, **overrides)
    return PlanShapes(rows=kw["rows"], n_queries=kw["n_queries"],
                      n_shards=kw["n_shards"], n_leaves=kw["n_leaves"])


def _calibrate_both_layouts(store, rows_list, ms_by_layout,
                            n_queries=SHAPES["n_queries"]):
    """Record both layouts' resolved plans at each rows shape."""
    for rows in rows_list:
        pm, qr = _candidates(rows=rows, n_queries=n_queries)
        shapes = _ctx(rows=rows, n_queries=n_queries)
        store.record(pm, ms_by_layout["point_major"](rows), shapes)
        store.record(qr, ms_by_layout["query_routed"](rows), shapes)


# ---------------------------------------------------------------------------
# calibration store
# ---------------------------------------------------------------------------


def test_calibration_store_records_and_roundtrips():
    store = CalibrationStore()
    assert not store.dirty and len(store) == 0
    pm, qr = _candidates()
    store.record(pm, 10.0)
    store.record(pm, 20.0, shapes=_ctx())
    store.record(qr, 5.0, shapes=_ctx())
    assert store.dirty and len(store) == 3  # (sig, shapes) keys
    # exact-signature consult aggregates across the shapes measured at
    agg = store.lookup(pm)
    assert agg["count"] == 2 and agg["total_ms"] == 30.0
    assert agg["min_ms"] == 10.0 and agg["max_ms"] == 20.0
    assert agg["last_ms"] == 20.0
    assert store.mean_ms(pm) == pytest.approx(15.0)
    assert store.mean_ms(qr) == pytest.approx(5.0)
    # snapshot keys on the signature string; shapes ride along when known
    snap = store.snapshot()
    assert len(snap) == 2
    pm_key = [k for k in snap if k.startswith("point_major/")][0]
    assert snap[pm_key]["mean_ms"] == pytest.approx(15.0)
    assert len(snap[pm_key]["shapes"]) == 1
    # JSON round trip preserves records, fit rows, and consult results
    restored = CalibrationStore.from_json(store.to_json())
    assert len(restored) == len(store)
    assert restored.mean_ms(pm) == pytest.approx(15.0)
    assert len(restored.fit_rows()) == len(store.fit_rows()) == 2
    assert not restored.dirty  # freshly loaded state is clean
    store.mark_clean()
    assert not store.dirty
    store.record(qr, 1.0)
    assert store.dirty


def test_observe_routes_to_explicit_store_even_when_empty():
    """Regression: an *empty* store is falsy (len 0) — observe() must
    still honour it rather than leaking into the module default."""
    pm, _ = _candidates()
    store = CalibrationStore()
    pm.observe(5.0, store=store, shapes=_ctx())
    assert len(store) == 1
    assert len(default_calibration()) == 0


def test_describe_reports_only_models_that_can_decide():
    """Regression: describe() must not claim observed/fitted provenance
    while only one layout is measured and the heuristic still decides."""
    store = CalibrationStore()
    pm, qr = _candidates()
    assert resolve_model("auto", store).describe() == "auto(heuristic)"
    store.record(pm, 5.0, shapes=_ctx())
    assert resolve_model("auto", store).describe() == "auto(heuristic)"
    store.record(qr, 5.0, shapes=_ctx())
    assert resolve_model("auto", store).describe() == "auto(observed)"
    _calibrate_both_layouts(
        store, [SHAPES["rows"] * 4],
        {"point_major": lambda r: 5.0, "query_routed": lambda r: 5.0},
    )
    assert resolve_model("auto", store).describe() == "auto(fitted)"
    assert resolve_model("fitted", store).describe() == "fitted"


def test_plan_use_observations_shim_removed():
    """The deprecated ``plan(use_observations=)`` spelling is gone (it
    warned for several releases); ``model=`` is the only spelling."""
    with pytest.raises(TypeError):
        make_plan(layout="auto", use_observations=True, **SHAPES)


def test_default_store_reset_between_tests_part1():
    """With the autouse guard, recordings here must not leak into any
    other test (its twin below asserts the store comes back empty)."""
    pm, _ = _candidates()
    default_calibration().record(pm, 123.0)
    assert len(default_calibration()) == 1


def test_default_store_reset_between_tests_part2():
    assert len(default_calibration()) == 0


# ---------------------------------------------------------------------------
# model selection and fallback
# ---------------------------------------------------------------------------


def test_fallback_chain_fitted_observed_heuristic():
    store = CalibrationStore()
    pm, qr = _candidates()
    ctx = _ctx()

    # empty store: everything falls through to the heuristic
    pick, kind = resolve_model("auto", store).decide((pm, qr), ctx)
    assert kind == "heuristic"
    heuristic_pick = pick.layout

    # one measured layout: observed cannot rank the pair -> heuristic
    store.record(pm, 100.0, shapes=ctx)
    pick, kind = resolve_model("auto", store).decide((pm, qr), ctx)
    assert kind == "heuristic" and pick.layout == heuristic_pick

    # both measured at ONE shape: fitted (needs 2 per layout) is not
    # ready -> the observed exact-signature model decides
    store.record(qr, 1.0, shapes=ctx)
    pick, kind = resolve_model("auto", store).decide((pm, qr), ctx)
    assert kind == "observed" and pick.layout == "query_routed"
    # explicitly requested fitted with <N observations: same fallback
    pick, kind = resolve_model("fitted", store).decide((pm, qr), ctx)
    assert kind == "observed" and pick.layout == "query_routed"

    # a second measured shape per layout: the fit becomes usable and
    # takes precedence over observed
    _calibrate_both_layouts(
        store, [SHAPES["rows"] * 4],
        {"point_major": lambda r: 400.0, "query_routed": lambda r: 1.0},
    )
    pick, kind = resolve_model("auto", store).decide((pm, qr), ctx)
    assert kind == "fitted" and pick.layout == "query_routed"

    # pinned models ignore the rest of the chain
    pick, kind = resolve_model("heuristic", store).decide((pm, qr), ctx)
    assert kind == "heuristic" and pick.layout == heuristic_pick
    with pytest.raises(ValueError):
        resolve_model("bogus", store)


def test_observed_no_matching_signature_falls_back_to_heuristic():
    """Observed data at one shape says nothing about a *different* plan
    signature — the chain must fall back to the heuristic there."""
    store = CalibrationStore()
    pm, qr = _candidates()
    store.record(pm, 100.0)
    store.record(qr, 1.0)
    other = _candidates(k=20)  # a different k: different plan signature
    assert store.mean_ms(other[0]) is None  # genuinely unmeasured
    pick, kind = resolve_model("observed", store).decide(other, _ctx())
    assert kind == "heuristic"
    assert pick.layout == make_plan(
        layout="auto", model="heuristic", **dict(SHAPES, k=20)
    ).layout


def test_fitted_overrides_heuristic_at_unmeasured_shape():
    """The acceptance case: calibrate at shapes A and B, then plan at an
    unmeasured nearby shape C — the fit generalizes and flips the
    heuristic's layout pick to the one the measurements imply."""
    rows_a, rows_b, rows_c = 65_536, 262_144, 131_072
    heuristic_at_c = make_plan(
        layout="auto", model="heuristic", **dict(SHAPES, rows=rows_c)
    )
    winner = ("query_routed" if heuristic_at_c.layout == "point_major"
              else "point_major")
    # measurements contradict the shape rules: the heuristic's pick is
    # slow (and grows with rows), the other layout is flat-cheap
    ms = {
        heuristic_at_c.layout: lambda r: 100.0 * r / rows_a,
        winner: lambda r: 1.0,
    }
    store = CalibrationStore()
    _calibrate_both_layouts(store, [rows_a, rows_b], ms)
    # C's signatures are genuinely unmeasured -> observed cannot decide
    c_pm, c_qr = _candidates(rows=rows_c)
    assert store.mean_ms(c_pm) is None or store.mean_ms(c_qr) is None
    pick, kind = resolve_model("auto", store).decide(
        (c_pm, c_qr), _ctx(rows=rows_c)
    )
    assert kind == "fitted" and pick.layout == winner
    # the full plan() path agrees, and differs from the heuristic's pick
    auto = make_plan(layout="auto", model="auto", calibration=store,
                     **dict(SHAPES, rows=rows_c))
    assert auto.layout == winner != heuristic_at_c.layout
    # predictions interpolate the measurements (A < C < B for the loser)
    fitted = FittedModel(store)
    loser_plan = c_pm if heuristic_at_c.layout == "point_major" else c_qr
    pred_c = fitted.predict_ms(loser_plan, _ctx(rows=rows_c))
    assert 100.0 < pred_c < 400.0


@settings(max_examples=12)
@given(
    ms_a=st.floats(min_value=0.5, max_value=50.0),
    slope=st.floats(min_value=0.0, max_value=8.0),
    n_queries=st.sampled_from([64, 256, 1024]),
    probes=st.integers(1, 3),
)
def test_fitted_predictions_monotone_in_rows_scanned(
    ms_a, slope, n_queries, probes
):
    """Property: whatever was measured, FittedModel predictions never
    decrease as rows_scanned grows (slope coefficients are clamped >= 0
    by the active-set refit)."""
    store = CalibrationStore()
    rows_grid = [32_768, 131_072, 524_288]
    for i, rows in enumerate(rows_grid):
        kw = dict(SHAPES, rows=rows, n_queries=n_queries, probes=probes)
        pm = make_plan(layout="point_major", **kw)
        shapes = _ctx(rows=rows, n_queries=n_queries)
        # ms grows (or stays flat) with rows at rate `slope`
        store.record(pm, ms_a + slope * i, shapes)
    fitted = FittedModel(store)
    assert fitted.ready("point_major")
    probe = make_plan(
        layout="point_major",
        **dict(SHAPES, rows=rows_grid[0], n_queries=n_queries,
               probes=probes),
    )
    preds = [
        fitted.predict_ms(probe, _ctx(rows=r, n_queries=n_queries))
        for r in (2 ** e for e in range(13, 23))
    ]
    assert all(a <= b + 1e-9 for a, b in zip(preds, preds[1:])), preds


def test_model_spellings_replace_use_observations():
    """``model="observed"`` / ``model="heuristic"`` cover what the removed
    ``use_observations=True/False`` shim used to mean."""
    pm, qr = _candidates()
    default_calibration().record(pm, 100.0)
    default_calibration().record(qr, 1.0)
    observed = make_plan(layout="auto", model="observed", **SHAPES)
    assert observed.layout == "query_routed"  # data wins over shape rules
    heuristic = make_plan(layout="auto", model="heuristic", **SHAPES)
    assert heuristic.layout == make_plan(
        layout="auto", model="heuristic", **SHAPES
    ).layout  # heuristic ignores observations entirely


# ---------------------------------------------------------------------------
# impl as a priced planning axis
# ---------------------------------------------------------------------------


def test_fused_rejected_for_query_routed():
    with pytest.raises(ValueError, match="query_routed"):
        SearchPlan(layout="query_routed", k=5, impl="fused")


def test_heuristic_flips_fused_vs_xla_with_scan_size():
    """``impl="auto"`` prices the fused fast path as one more planning
    axis: a short sweep can't amortise the flat launch/merge overhead
    (xla wins), a long sweep's per-wave carry traffic dominates (fused
    wins) — at shapes no calibration record has ever seen."""
    kw = dict(n_leaves=64, n_queries=256, n_shards=1, k=10,
              calibration=CalibrationStore(), model="heuristic")
    small = make_plan(layout="point_major", impl="auto", rows=8192, **kw)
    assert small.impl == "xla"
    big = make_plan(layout="point_major", impl="auto", rows=1_048_576, **kw)
    assert big.impl == "fused"
    # the codes scan flips on the same axis (rerank-deep carry per wave)
    ckw = dict(kw, code_m=8, code_bits=8, dim=32)
    csmall = make_plan(layout="scan_codes", impl="auto", rows=2048, **ckw)
    assert csmall.impl == "xla"
    cbig = make_plan(layout="scan_codes", impl="auto", rows=1_048_576, **ckw)
    assert cbig.impl == "fused"


def test_auto_layout_never_expands_fused_query_routed():
    """``layout="auto", impl="auto"`` candidate sets: dense layouts get
    xla+fused variants, query-routed stays xla-only (and an explicit
    ``impl="fused"`` skips the routed candidate entirely)."""
    kw = dict(SHAPES, calibration=CalibrationStore(), model="heuristic")
    p = make_plan(layout="auto", impl="auto", **kw)
    assert (p.layout, p.impl) != ("query_routed", "fused")
    forced = make_plan(layout="auto", impl="fused", **kw)
    assert forced.layout != "query_routed" and forced.impl == "fused"


def test_fitted_prices_impl_curves_independently():
    """FittedModel fits one curve per (layout, impl): fused measurements
    never contaminate the xla curve, and an unmeasured impl is
    unpriceable (the chain falls through rather than guessing)."""
    store = CalibrationStore()
    rows_grid = [SHAPES["rows"], SHAPES["rows"] * 4]
    for rows in rows_grid:
        xla = make_plan(layout="point_major", impl="xla",
                        **dict(SHAPES, rows=rows))
        fused = make_plan(layout="point_major", impl="fused",
                          **dict(SHAPES, rows=rows))
        store.record(xla, rows / 1000.0, _ctx(rows=rows))
        store.record(fused, rows / 4000.0, _ctx(rows=rows))
    fitted = FittedModel(store)
    assert fitted.ready("point_major")
    probe_rows = SHAPES["rows"] * 2
    xla_p = make_plan(layout="point_major", impl="xla",
                      **dict(SHAPES, rows=probe_rows))
    fused_p = make_plan(layout="point_major", impl="fused",
                        **dict(SHAPES, rows=probe_rows))
    ctx = _ctx(rows=probe_rows)
    assert fitted.predict_ms(fused_p, ctx) < fitted.predict_ms(xla_p, ctx)
    # the pallas impl has no curve -> None, never an extrapolated guess
    pallas_p = make_plan(layout="point_major", impl="pallas",
                         **dict(SHAPES, rows=probe_rows))
    assert fitted.predict_ms(pallas_p, ctx) is None


# ---------------------------------------------------------------------------
# calibration decay window + autotuned tile configs
# ---------------------------------------------------------------------------

STALE_AGE_S = (costmodel_lib.CALIBRATION_MAX_AGE_HALF_LIVES + 1) * \
    costmodel_lib.CALIBRATION_HALF_LIFE_S


def test_stale_records_age_out_of_consults_and_fits():
    store = CalibrationStore()
    pm, _ = _candidates()
    store.record(pm, 10.0, shapes=_ctx(), ts=time.time() - STALE_AGE_S)
    # stale: the exact-shape consult misses and the fit never sees it
    assert store.mean_ms(pm, _ctx()) is None
    assert store.fit_rows() == []
    assert len(store) == 1  # the record itself is kept (reporting views)
    # a fresh fold revives the record (timestamps are max-folded)
    store.record(pm, 20.0, shapes=_ctx())
    assert store.mean_ms(pm, _ctx()) == pytest.approx(15.0)
    assert len(store.fit_rows()) == 1


def test_fitted_ignores_stale_only_calibration():
    store = CalibrationStore()
    old = time.time() - STALE_AGE_S
    for rows in (SHAPES["rows"], SHAPES["rows"] * 4):
        pm, qr = _candidates(rows=rows)
        store.record(pm, rows / 1000.0, _ctx(rows=rows), ts=old)
        store.record(qr, rows / 1000.0, _ctx(rows=rows), ts=old)
    assert not FittedModel(store).ready("point_major")


def test_calibration_timestamps_roundtrip_and_legacy_loads_fresh():
    store = CalibrationStore()
    pm, _ = _candidates()
    ts = time.time() - 3600.0
    store.record(pm, 10.0, shapes=_ctx(), ts=ts)
    payload = store.to_json()
    restored = CalibrationStore.from_json(payload)
    (_, stats, _), = restored.fit_rows()
    assert stats["ts"] == pytest.approx(ts)
    # a format-1 payload (no timestamps) loads as fresh: an undated
    # measurement beats no calibration, and it ages out from here
    legacy = {
        "format": 1,
        "records": [
            {"signature": rec["signature"],
             "stats": {k: v for k, v in rec["stats"].items() if k != "ts"},
             "shapes": rec["shapes"]}
            for rec in payload["records"]
        ],
    }
    relived = CalibrationStore.from_json(legacy)
    assert relived.mean_ms(pm, _ctx()) == pytest.approx(10.0)
    assert len(relived.fit_rows()) == 1


def test_tile_configs_record_consult_decay_and_roundtrip():
    store = CalibrationStore()
    store.mark_clean()
    assert store.tile_config("point_major", 24, "float32") is None
    store.record_tile_config("point_major", 24, "float32", 512, 3.5)
    assert store.dirty  # tuned tiles alone are commit-worthy
    cfg = store.tile_config("point_major", 24, "float32")
    assert cfg["block_rows"] == 512 and cfg["ms"] == pytest.approx(3.5)
    # stale tunings age out on the same window as measurements
    store.record_tile_config("point_major", 24, "bfloat16", 2048, 1.0,
                             ts=time.time() - STALE_AGE_S)
    assert store.tile_config("point_major", 24, "bfloat16") is None
    assert len(store.tile_configs()) == 2  # reporting view keeps both
    restored = CalibrationStore.from_json(store.to_json())
    rcfg = restored.tile_config("point_major", 24, "float32")
    assert rcfg == cfg
    # merge: newest tuning wins
    newer = CalibrationStore()
    newer.record_tile_config("point_major", 24, "float32", 1024, 2.0)
    store.merge(newer)
    assert store.tile_config("point_major", 24, "float32")["block_rows"] \
        == 1024


def test_plan_fused_candidate_honours_tuned_tile_config():
    """A tuned block size steers the fused candidate's budget (snapped to
    a shard-rows divisor); a caller-pinned ``block_rows`` wins over it."""
    store = CalibrationStore()
    kw = dict(SHAPES, calibration=store)
    default_fused = make_plan(layout="point_major", impl="fused", **kw)
    store.record_tile_config(
        "point_major", 0, "float32", 512, 1.0
    )
    tuned = make_plan(layout="point_major", impl="fused", **kw)
    assert tuned.block_rows == 512 != default_fused.block_rows
    # non-divisor tunings snap down onto the shard grid
    store.record_tile_config("point_major", 0, "float32", 3000, 1.0)
    snapped = make_plan(layout="point_major", impl="fused", **kw)
    assert SHAPES["rows"] % snapped.block_rows == 0
    assert snapped.block_rows <= 3000
    pinned = make_plan(layout="point_major", impl="fused",
                       block_rows=2048, **kw)
    assert pinned.block_rows == 2048


# ---------------------------------------------------------------------------
# per-shard budget scaling helpers
# ---------------------------------------------------------------------------


def test_scale_slab_budget_grows_never_shrinks():
    # a big batch leaves q_cap well under the probe-expanded query rows,
    # so there is real headroom to grow into
    pm, qr = _candidates(n_queries=4096)
    kw = dict(n_queries=4096, shard_rows=SHAPES["rows"])
    assert scale_slab_budget(pm, 1.0, **kw) is pm
    assert scale_slab_budget(pm, 0.5, **kw) is pm  # never shrink
    grown = scale_slab_budget(pm, 1.5, **kw)
    assert grown.layout == "point_major"
    assert grown.q_cap >= int(pm.q_cap * 1.5) and grown.q_cap % 8 == 0
    assert grown.block_rows == pm.block_rows  # only the slab budget moves
    # growth caps at the probe-expanded query rows: a slab never pads
    # dead rows past the real batch
    maxed = scale_slab_budget(pm, 100.0, **kw)
    assert maxed.q_cap == 4096 * pm.probes
    grown_qr = scale_slab_budget(qr, 2.0, n_queries=4096,
                                 shard_rows=qr.p_cap + 8)
    assert grown_qr.p_cap == qr.p_cap + 8  # capped at the shard rows
    assert grown_qr.q_tile == qr.q_tile


def test_shard_slab_scales_uniform_until_fitted():
    pm_a, _ = _candidates()
    pm_b, _ = _candidates(rows=SHAPES["rows"] * 2)
    shapes = [_ctx(), _ctx(rows=SHAPES["rows"] * 2)]
    # no fit -> uniform
    assert shard_slab_scales(None, [pm_a, pm_b], shapes) == [1.0, 1.0]
    store = CalibrationStore()
    assert fitted_component("auto", store) is None
    assert fitted_component("heuristic", store) is None
    # calibrate: cost grows with rows -> the bigger shard earns headroom
    _calibrate_both_layouts(
        store, [SHAPES["rows"], SHAPES["rows"] * 4],
        {"point_major": lambda r: r / 1000.0,
         "query_routed": lambda r: r / 1000.0},
    )
    fitted = fitted_component("auto", store)
    assert fitted is not None
    scales = shard_slab_scales(fitted, [pm_a, pm_b], shapes)
    assert scales[0] == 1.0  # at/below mean: keep the derived default
    assert 1.0 < scales[1] <= 2.0  # pricier shard: more slab headroom


# ---------------------------------------------------------------------------
# index-scoped calibration through the lifecycle, and bit-identity
# ---------------------------------------------------------------------------

DIM = 24


@pytest.fixture(scope="module")
def corpus():
    vecs_np, _ = synth.sample_descriptors(3000, DIM, seed=0, n_centers=50)
    vecs = jnp.asarray(vecs_np)
    tree = build_tree(vecs, (8, 4), key=jax.random.PRNGKey(1))
    mesh = local_mesh()
    built = build_index(vecs, tree, mesh, wire_dtype=jnp.float32)
    q_np = np.array(vecs[:48]) + np.random.default_rng(2).standard_normal(
        (48, DIM)
    ).astype(np.float32)
    return vecs_np, tree, mesh, built, q_np


def test_calibration_survives_the_index_lifecycle(tmp_path, corpus):
    """Recorded during serving (post-warmup only) → persisted by commit →
    reloaded by open → carried through compact."""
    from repro.serving import SearchSession

    vecs_np, tree, mesh, built, q_np = corpus
    d = str(tmp_path / "idx")
    idx = Index.create(tree, d, mesh=mesh)
    idx.append(vecs_np[:2000])
    idx.append(vecs_np[2000:])
    v0 = idx.commit()

    s = SearchSession(idx, k=5, layout="point_major", buckets=(48,),
                      cost_model="heuristic")
    # pre-warmup dispatches must NOT record (compile-tainted timings)
    s.search(q_np, n_images=4)
    assert len(idx.calibration) == 0 and not idx.calibration.dirty
    s.warmup()
    s.search(q_np, n_images=4)
    # one record per executed segment plan, ms attributed by rows share,
    # keyed at the shapes a later per-segment plan() consult will use
    expected = {(plan_signature(p), r)
                for p, r, _ in s._runtimes[48].plan_rows}
    assert len(idx.calibration) == len(expected) and idx.calibration.dirty
    sigs = {plan_signature(p) for p in s._runtimes[48].plans}
    recs = idx.calibration.fit_rows()
    assert {r[0] for r in recs} <= sigs
    assert all(r[2].n_queries == 48 for r in recs)
    # the observed model's exact-shape consult must find every executed
    # plan at the shapes a later per-segment plan() will ask about
    for p, seg_rows, n_sh in s._runtimes[48].plan_rows:
        assert idx.calibration.mean_ms(
            p, PlanShapes(rows=seg_rows, n_queries=48, n_shards=n_sh,
                          n_leaves=idx.n_leaves)
        ) is not None
    n_recs = len(idx.calibration)

    # calibration alone is commit-worthy, and the bump is durable
    v1 = idx.commit()
    assert v1 == v0 + 1 and not idx.calibration.dirty
    reopened = Index.open(d, mesh=mesh)
    assert len(reopened.calibration) == n_recs
    assert reopened.calibration.mean_ms(s._runtimes[48].plan) == (
        pytest.approx(idx.calibration.mean_ms(s._runtimes[48].plan))
    )

    # compact() carries the store into the new manifest
    reopened.compact()
    assert len(reopened.calibration) == n_recs
    recompacted = Index.open(d, mesh=mesh)
    assert len(recompacted.calibration) == n_recs
    # idempotent commit: clean calibration does not bump the version
    v2 = recompacted.version
    assert recompacted.commit() == v2


@pytest.mark.parametrize("cost_model",
                         ["heuristic", "observed", "fitted", "auto"])
def test_search_bit_identical_under_every_cost_model(corpus, cost_model):
    """The model picks plans, never results: with a populated calibration
    store (fitted active, per-shard scales live), Index.search and
    ShardedIndex.search return bit-identical ids+dists under every
    cost-model setting, and sharded == unsharded within each."""
    vecs_np, tree, mesh, built, q_np = corpus
    idx = Index.create(tree, None, mesh=mesh)
    idx.append(vecs_np[:1200])
    idx.append(vecs_np[1200:2100])
    idx.append(vecs_np[2100:])
    idx.commit()
    ref = idx.search(q_np, k=5, probes=2, cost_model="heuristic")
    # calibrate both layouts at two shapes (cost rises with rows) so the
    # fitted model is ready and shard scales deviate from uniform
    seg_rows = [v.rows for v in idx.segment_views()]
    for rows in (min(seg_rows), max(seg_rows) * 4):
        for layout in ("point_major", "query_routed"):
            p = make_plan(rows=rows, n_leaves=idx.n_leaves,
                          n_queries=len(q_np), n_shards=1, k=5, probes=2,
                          layout=layout)
            idx.calibration.record(
                p, rows / 500.0,
                PlanShapes(rows=rows, n_queries=len(q_np), n_shards=1,
                           n_leaves=idx.n_leaves),
            )
    assert fitted_component(cost_model, idx.calibration) is not None or \
        cost_model in ("heuristic", "observed")
    got = idx.search(q_np, k=5, probes=2, cost_model=cost_model)
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(got.dists),
                                  np.asarray(ref.dists))
    for shards in (2, 3):
        sharded = ShardedIndex(idx, n_shards=shards)
        res = sharded.search(q_np, k=5, probes=2, cost_model=cost_model)
        np.testing.assert_array_equal(np.asarray(res.ids),
                                      np.asarray(ref.ids))
        np.testing.assert_array_equal(np.asarray(res.dists),
                                      np.asarray(ref.dists))
    # a caller-pinned slab budget is never scaled by fitted per-shard
    # headroom: pinned sharded == pinned unsharded even with a warm fit
    pinned_ref = idx.search(q_np, k=5, layout="point_major", q_cap=64,
                            cost_model=cost_model)
    pinned = ShardedIndex(idx, n_shards=2).search(
        q_np, k=5, layout="point_major", q_cap=64, cost_model=cost_model
    )
    np.testing.assert_array_equal(np.asarray(pinned.ids),
                                  np.asarray(pinned_ref.ids))
    np.testing.assert_array_equal(np.asarray(pinned.dists),
                                  np.asarray(pinned_ref.dists))


@pytest.mark.parametrize("cost_model", ["heuristic", "auto"])
def test_sessions_bit_identical_under_cost_models(corpus, cost_model):
    """Serving sessions (unsharded and scatter-gather) under a populated
    calibration store: identical results to the heuristic baseline, zero
    steady-state recompiles, and post-warmup dispatches keep recording."""
    from repro.serving import SearchSession, ShardedSearchSession

    vecs_np, tree, mesh, built, q_np = corpus
    idx = Index.create(tree, None, mesh=mesh)
    idx.append(vecs_np[:1500])
    idx.append(vecs_np[1500:])
    idx.commit()
    baseline = SearchSession(idx, k=5, probes=2, buckets=(48,),
                             cost_model="heuristic")
    baseline.warmup()
    ref_ids, ref_dists = baseline.search(q_np)
    # the baseline's own post-warmup dispatch has already begun calibrating
    assert len(idx.calibration) >= 0
    for rows in (2048, 8192):
        for layout in ("point_major", "query_routed"):
            p = make_plan(rows=rows, n_leaves=idx.n_leaves, n_queries=48,
                          n_shards=1, k=5, probes=2, layout=layout)
            idx.calibration.record(
                p, rows / 100.0,
                PlanShapes(rows=rows, n_queries=48, n_shards=1,
                           n_leaves=idx.n_leaves),
            )
    s = SearchSession(idx, k=5, probes=2, buckets=(48,),
                      cost_model=cost_model)
    s.warmup()
    ids, dists = s.search(q_np, n_images=6)
    np.testing.assert_array_equal(ids, ref_ids)
    np.testing.assert_array_equal(dists, ref_dists)
    assert s.steady_state_recompiles() == 0
    assert s.active_cost_model().startswith(cost_model)
    sh = ShardedSearchSession(idx, shards=2, k=5, probes=2, buckets=(48,),
                              cost_model=cost_model)
    sh.warmup()
    ids, dists = sh.search(q_np, n_images=6)
    np.testing.assert_array_equal(ids, ref_ids)
    np.testing.assert_array_equal(dists, ref_dists)
    assert sh.steady_state_recompiles() == 0
