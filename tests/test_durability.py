"""Crash-at-every-boundary durability tests (docs/dynamicity.md).

For each lifecycle operation — append+commit, delete+commit, full
compact, incremental compact, enable_codes+commit — enumerate every
write/fsync/link/rename/unlink the op performs under the index directory
(``tests/faults.py``), crash at each one in turn, and assert the
recovery invariant:

  *reopening the directory always yields exactly the last published
  manifest* — either the pre-op or the post-op version, bit-identical
  search results to the corresponding reference, the exact published
  segment set (no torn hybrid, no resurrected orphan) — and the
  surviving handle can retry the op to completion (or learns via
  ``FileExistsError`` that its first attempt already landed).
"""

import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from faults import FaultFS, InjectedFault
from repro.core.tree import build_tree
from repro.index import Index

DIM = 8
K = 3
_rng = np.random.default_rng(11)
VEC_A = _rng.standard_normal((96, DIM)).astype(np.float32)   # ids 0..95
VEC_B = _rng.standard_normal((64, DIM)).astype(np.float32)   # ids 96..159
VEC_C = _rng.standard_normal((48, DIM)).astype(np.float32)   # ids 160..207
QUERIES = _rng.standard_normal((4, DIM)).astype(np.float32)


def _build_base(d: str) -> None:
    """Pristine fixture state: two committed segments + committed
    tombstones over the first (24/96 dead = exactly the default policy's
    tombstone-ratio trigger, so incremental compaction has work)."""
    tree = build_tree(jnp.asarray(VEC_A), (4, 2), key=jax.random.PRNGKey(0))
    idx = Index.create(tree, d)
    idx.append(VEC_A, ids=np.arange(96))
    idx.commit()
    idx.append(VEC_B, ids=np.arange(96, 160))
    idx.commit()
    idx.delete(np.arange(24))
    idx.commit()


@pytest.fixture(scope="module")
def pristine(tmp_path_factory) -> str:
    d = str(tmp_path_factory.mktemp("durability") / "base")
    _build_base(d)
    return d


def _probe(d: str):
    """(version, segment names, search ids, search dists) read fresh from
    disk — the recovery observer."""
    idx = Index.open(d)
    r = idx.search(QUERIES, k=K)
    return (
        idx.version,
        tuple(s.name for s in idx.segments),
        np.asarray(r.ids).copy(),
        np.asarray(r.dists).copy(),
    )


# Each op takes (idx, ctx); ctx makes the *staging* half idempotent so a
# retry after a mid-staging crash doesn't double-append — exactly how a
# recovering writer would replay its intent log.
def _op_append(idx, ctx):
    if not ctx.get("appended"):
        idx.append(VEC_C, ids=np.arange(160, 208))
        ctx["appended"] = True
    idx.commit()


def _op_delete(idx, ctx):
    idx.delete(np.arange(100, 140))  # idempotent by contract
    idx.commit()


def _op_compact_full(idx, ctx):
    idx.compact()


def _op_compact_incremental(idx, ctx):
    idx.compact(incremental=True)


def _op_enable_codes(idx, ctx):
    if not ctx.get("enabled"):
        idx.enable_codes(m=2, bits=4, seed=0)
        ctx["enabled"] = True
    idx.commit()


OPS = {
    "append": _op_append,
    "delete": _op_delete,
    "compact_full": _op_compact_full,
    "compact_incremental": _op_compact_incremental,
    "enable_codes": _op_enable_codes,
}


@pytest.mark.parametrize("opname", sorted(OPS))
def test_crash_at_every_write_boundary(pristine, tmp_path, opname):
    op = OPS[opname]

    # references: the pre state, and the post state from a fault-free run
    pre = _probe(pristine)
    post_dir = str(tmp_path / "post")
    shutil.copytree(pristine, post_dir)
    op(Index.open(post_dir), {})
    post = _probe(post_dir)
    assert post[0] > pre[0], "fixture op must publish a new version"

    # counting pass: how many crash points does this op have?
    count_dir = str(tmp_path / "count")
    shutil.copytree(pristine, count_dir)
    with FaultFS(count_dir) as fs:
        op(Index.open(count_dir), {})
    boundaries = list(fs.boundaries)
    assert len(boundaries) >= 4, boundaries  # stage + fsync + publish, minimum

    for i, bound in enumerate(boundaries):
        work = str(tmp_path / f"crash_{i}")
        shutil.copytree(pristine, work)
        idx = Index.open(work)
        ctx: dict = {}
        crashed = True
        with FaultFS(work, fail_at=i) as fs:
            try:
                op(idx, ctx)
                crashed = False
            except InjectedFault:
                pass
        assert fs.fired, (i, bound)
        if not crashed:
            # the boundary sits inside a best-effort cleanup guard
            # (post-publish gc): absorbing the fault means the op had
            # already landed — disk must be exactly post
            got = _probe(work)
            assert got[0] == post[0] and got[1] == post[1], (i, bound)
            assert np.array_equal(got[2], post[2]), (i, bound)
            shutil.rmtree(work)
            continue

        # recovery invariant: disk is exactly pre or exactly post
        got = _probe(work)
        if got[0] == pre[0]:
            ref = pre
        elif got[0] == post[0]:
            ref = post
        else:
            pytest.fail(f"boundary {i} ({bound}): reopened v{got[0]}, "
                        f"want v{pre[0]} or v{post[0]}")
        assert got[1] == ref[1], (i, bound)  # exact published segment set
        assert np.array_equal(got[2], ref[2]), (i, bound)
        assert np.array_equal(got[3], ref[3]), (i, bound)

        # retry on the surviving handle: completes, or reports the first
        # attempt already landed — either way disk converges to post
        try:
            op(idx, ctx)
        except FileExistsError:
            assert _probe(work)[0] == post[0], (i, bound)
        after = _probe(work)
        assert after[0] >= post[0], (i, bound)
        assert np.array_equal(after[2], post[2]), (i, bound)
        assert np.array_equal(after[3], post[3]), (i, bound)

        # a recovered index can gc any crash debris and stay serveable
        Index.open(work).gc()
        final = _probe(work)
        assert np.array_equal(final[2], post[2]), (i, bound)
        shutil.rmtree(work)


def test_boundary_kinds_cover_publish_protocol(pristine, tmp_path):
    """The harness actually sees the protocol's moving parts: staging
    opens, the manifest fsync, and the exclusive-link publish."""
    work = str(tmp_path / "kinds")
    shutil.copytree(pristine, work)
    with FaultFS(work) as fs:
        _op_append(Index.open(work), {})
    kinds = {k for k, _ in fs.boundaries}
    assert {"open", "fsync", "link", "rename", "unlink"} <= kinds, fs.boundaries
