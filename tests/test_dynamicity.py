"""Read-during-write lifecycle tests (docs/dynamicity.md).

* Pinned-version serving: a (sharded) session interleaved with a seeded
  append/delete/incremental-compact schedule keeps answering bit-identically
  to the facade search of its pinned manifest version, adopts new versions
  only at ``maybe_refresh()``, and never recompiles in steady state.
* Incremental compaction: the size-tier/tombstone-ratio policy reclaims a
  90%-deleted segment in one step without touching its neighbours and
  without perturbing search results.
* Recovery regressions: a staged-but-unpublished segment is invisible to
  ``Index.open`` and does not block later appends; ``Index.gc`` lists
  exactly the unreachable artifacts under ``dry_run`` and removes them
  otherwise.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.tree import build_tree
from repro.index import CompactionPolicy, Index
from repro.obs import get_registry
from repro.serving import SearchSession
from repro.serving.sharded import ShardedSearchSession

DIM = 16
B = 64  # bucket == batch rows: facade and session plan identically
K = 5
SEARCH_KW = dict(layout="point_major", probes=2, cost_model="heuristic")

_rng = np.random.default_rng(23)
VECS = _rng.standard_normal((1200, DIM)).astype(np.float32)
QUERIES = (VECS[:B] + 0.01 * _rng.standard_normal((B, DIM))).astype(np.float32)


def _make_index(d: str, n_committed: int = 600) -> Index:
    tree = build_tree(jnp.asarray(VECS[:512]), (8, 4),
                      key=jax.random.PRNGKey(0))
    idx = Index.create(tree, d)
    half = n_committed // 2
    idx.append(VECS[:half], ids=np.arange(half))
    idx.commit()
    idx.append(VECS[half:n_committed], ids=np.arange(half, n_committed))
    idx.commit()
    return idx


def _facade(idx: Index):
    r = idx.search(QUERIES, k=K, **SEARCH_KW)
    return np.asarray(r.ids).copy(), np.asarray(r.dists).copy()


# ---------------------------------------------------------------------------
# pinned-version serving under a concurrent mutation schedule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shards", [1, 2, 3])
def test_mutate_while_serve_bit_identical(tmp_path, shards):
    idx = _make_index(str(tmp_path / "idx"))
    kw = dict(buckets=(B,), k=K, **SEARCH_KW)
    if shards == 1:
        session = SearchSession(idx, **kw)
    else:
        session = ShardedSearchSession(idx, shards=shards, **kw)
    session.warmup()
    v0 = session.pinned_version
    expected = _facade(idx)

    rng = np.random.default_rng(100 + shards)
    next_row = 600  # VECS[600:] is the append reserve
    live = list(range(600))
    for step in range(6):
        op = rng.choice(["append", "delete", "compact", "noop"])
        mutated = False
        if op == "append" and next_row + 100 <= len(VECS):
            idx.append(VECS[next_row:next_row + 100],
                       ids=np.arange(next_row, next_row + 100))
            idx.commit()
            live += list(range(next_row, next_row + 100))
            next_row += 100
            mutated = True
        elif op == "delete" and len(live) > 200:
            kill = rng.choice(live, size=40, replace=False)
            idx.delete(kill)
            idx.commit()
            live = sorted(set(live) - set(int(i) for i in kill))
            mutated = True
        elif op == "compact":
            v_before = idx.version
            idx.compact(incremental=True)
            mutated = idx.version != v_before

        # the pin holds: every response equals the pinned version's
        # facade answer no matter what just landed underneath
        ids, dists = session.search(QUERIES)
        assert session.pinned_version == v0, (step, op)
        assert np.array_equal(ids, expected[0]), (step, op)
        assert np.array_equal(dists, expected[1]), (step, op)

        refreshed = session.maybe_refresh()
        assert refreshed == mutated, (step, op)
        if refreshed:
            v0 = session.pinned_version
            expected = _facade(idx)
        ids, dists = session.search(QUERIES)
        assert np.array_equal(ids, expected[0]), (step, op, "post-refresh")
        assert np.array_equal(dists, expected[1]), (step, op, "post-refresh")

    assert session.steady_state_recompiles() == 0
    # adopting did not desync the pin bookkeeping
    assert session.maybe_refresh() is False


def test_session_pin_survives_compaction_gc(tmp_path):
    """The pinned snapshot keeps serving even after an incremental compact
    *garbage-collects the pinned segments' directories*: views and row
    data were captured in memory at pin time."""
    idx = _make_index(str(tmp_path / "idx"))
    session = SearchSession(idx, buckets=(B,), k=K, **SEARCH_KW)
    session.warmup()
    expected = _facade(idx)
    old_names = {s.name for s in idx.segments}

    idx.delete(np.arange(0, 120))
    idx.commit()
    while True:
        v = idx.version
        idx.compact(incremental=True)
        if idx.version == v:
            break
    assert {s.name for s in idx.segments} != old_names

    ids, dists = session.search(QUERIES)
    assert np.array_equal(ids, expected[0])
    assert np.array_equal(dists, expected[1])
    assert session.maybe_refresh() is True
    ids, dists = session.search(QUERIES)
    post = _facade(idx)
    assert np.array_equal(ids, post[0])


# ---------------------------------------------------------------------------
# incremental compaction policy
# ---------------------------------------------------------------------------

def test_tombstone_heavy_segment_reclaimed_in_one_step(tmp_path):
    idx = _make_index(str(tmp_path / "idx"))
    a_name, b_name = [s.name for s in idx.segments]
    # kill 90% of segment B's rows
    idx.delete(np.arange(300, 570))
    idx.commit()
    assert get_registry().gauge("index.tombstones_live").value == 270
    assert get_registry().counter("index.tombstoned").value == 270
    before = _facade(idx)

    merged = idx.compact(incremental=True)
    assert merged is not None
    names = [s.name for s in idx.segments]
    assert a_name in names, "untouched neighbour must survive by name"
    assert b_name not in names, "tombstone-heavy victim must be replaced"
    assert idx.tombstones.size == 0, "victims' tombstones are dropped"
    assert get_registry().gauge("index.tombstones_live").value == 0
    after = _facade(idx)
    assert np.array_equal(before[0], after[0])
    assert np.array_equal(before[1], after[1])

    reopened = _facade(Index.open(str(tmp_path / "idx")))
    assert np.array_equal(reopened[0], after[0])
    assert np.array_equal(reopened[1], after[1])


def test_policy_selects_smallest_size_tier(tmp_path):
    idx = _make_index(str(tmp_path / "idx"))  # 300 + 300
    idx.append(VECS[600:640], ids=np.arange(600, 640))
    idx.commit()
    idx.append(VECS[640:672], ids=np.arange(640, 672))
    idx.commit()
    pol = CompactionPolicy()
    victims = pol.select(idx.segments, idx.tombstones)
    assert [s.valid_rows for s in victims] == [40, 32]

    merged = idx.compact(incremental=True, policy=pol)
    assert merged is not None
    assert sorted(s.valid_rows for s in idx.segments) == [72, 300, 300]
    # fixed point: nothing small enough to tier together any more
    v = idx.version
    assert idx.compact(incremental=True, policy=pol) is None
    assert idx.version == v


def test_policy_empty_and_thresholds():
    pol = CompactionPolicy(tombstone_ratio=0.5, min_tier_segments=3)
    assert pol.select([], np.array([], np.int64)) == []


# ---------------------------------------------------------------------------
# recovery regressions: staged orphans + gc
# ---------------------------------------------------------------------------

def test_open_ignores_staged_unpublished_segment(tmp_path):
    d = str(tmp_path / "idx")
    idx = _make_index(d)
    v = idx.version
    committed = {s.name for s in idx.segments}
    expected = _facade(idx)

    # a second writer stages (saves) a segment but dies before commit
    other = Index.open(d)
    other.append(VECS[600:700], ids=np.arange(600, 700))
    orphan = other._staged[-1].name
    del other

    reopened = Index.open(d)
    assert reopened.version == v
    assert {s.name for s in reopened.segments} == committed
    got = _facade(reopened)
    assert np.array_equal(got[0], expected[0])

    # the orphan's name stays reserved: a later append can never collide
    reopened.append(VECS[700:760], ids=np.arange(700, 760))
    assert reopened._staged[-1].name != orphan
    reopened.commit()
    assert orphan not in {s.name for s in reopened.segments}


def test_open_directory_with_only_staged_segment(tmp_path):
    d = str(tmp_path / "empty")
    tree = build_tree(jnp.asarray(VECS[:512]), (8, 4),
                      key=jax.random.PRNGKey(0))
    idx = Index.create(tree, d)
    idx.append(VECS[:100], ids=np.arange(100))  # staged, never committed
    del idx

    reopened = Index.open(d)
    assert reopened.segments == ()
    reopened.append(VECS[:100], ids=np.arange(100))
    reopened.commit()
    assert len(reopened.segments) == 1
    r = reopened.search(QUERIES, k=K, **SEARCH_KW)
    assert np.asarray(r.ids).shape == (B, K)


def test_gc_dry_run_then_collect(tmp_path):
    d = str(tmp_path / "idx")
    idx = _make_index(d)
    # manufacture garbage: superseded manifests already exist (v1..v-1);
    # add an orphan segment from a dead writer
    other = Index.open(d)
    other.append(VECS[600:660], ids=np.arange(600, 660))
    del other

    idx2 = Index.open(d)
    expected = _facade(idx2)
    report = idx2.gc(dry_run=True)
    assert report["manifests"], "superseded manifests are collectable"
    assert report["segments"], "orphan segment is collectable"
    # dry run deleted nothing (order-insensitive: listdir order is free)
    def _norm(rep):
        return {key: sorted(v) for key, v in rep.items()}

    again = idx2.gc(dry_run=True)
    assert _norm(again) == _norm(report)

    collected = idx2.gc()
    assert _norm(collected) == _norm(report)
    assert idx2.gc(dry_run=True) == {
        "manifests": [], "segments": [], "tombstones": [], "codes": [],
        "tmp": [],
    }
    got = _facade(Index.open(d))
    assert np.array_equal(got[0], expected[0])
    assert np.array_equal(got[1], expected[1])


def test_gc_keeps_own_staged_segment(tmp_path):
    d = str(tmp_path / "idx")
    idx = _make_index(d)
    idx.append(VECS[600:660], ids=np.arange(600, 660))  # staged, not committed
    staged = idx._staged[-1].name
    report = idx.gc()
    assert all(staged not in rel for rel in report["segments"])
    idx.commit()
    assert staged in {s.name for s in idx.segments}


# ---------------------------------------------------------------------------
# search-time pruning
# ---------------------------------------------------------------------------

def test_zero_live_segment_pruned_result_identical(tmp_path):
    idx = _make_index(str(tmp_path / "idx"))
    before = _facade(idx)
    idx.delete(np.arange(300, 600))  # all of segment B
    idx.commit()
    mid = _facade(idx)
    pruned = get_registry().counter("index.segments_pruned").value
    assert pruned >= 1
    # B contributed nothing dead-masked either way; A's results unchanged
    # wherever B's ids don't appear
    assert not np.isin(mid[0], np.arange(300, 600)).any()

    # sharded facade prunes too
    from repro.index import ShardedIndex, ShardPlan
    sh = ShardedIndex(idx, plan=ShardPlan.for_index(idx, 2))
    r = sh.search(QUERIES, k=K, **SEARCH_KW)
    assert np.array_equal(np.asarray(r.ids), mid[0])
    assert np.array_equal(np.asarray(r.dists), mid[1])
