"""Engine subsystem: plans, executors on the shared tile-scan core,
multi-probe correctness/recall, and exact pairs/overflow accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dispatch import combine_rows, dispatch_rows, make_dispatch
from repro.core.engine import SearchPlan, largest_divisor_leq, plan
from repro.core.index_build import build_index
from repro.core.lookup import build_lookup, probe_leaves
from repro.core.search import batch_search
from repro.core.tree import build_tree, tree_assign
from repro.data import synth
from repro.distributed.meshutil import local_mesh

LAYOUTS = ("point_major", "query_routed")


@pytest.fixture(scope="module")
def corpus():
    vecs_np, _ = synth.sample_descriptors(3000, 24, seed=0, n_centers=50)
    vecs = jnp.asarray(vecs_np)
    tree = build_tree(vecs, (8, 4), key=jax.random.PRNGKey(1))
    mesh = local_mesh()
    index = build_index(vecs, tree, mesh, wire_dtype=jnp.float32)
    q_np = np.array(vecs[:80]) + np.random.default_rng(2).standard_normal(
        (80, vecs.shape[1])
    ).astype(np.float32)
    return vecs, tree, mesh, index, q_np


def multiprobe_oracle(vecs, tree, q_np, probes, k):
    """Brute force over the union of each query's ``probes`` leaves."""
    leaves = np.array(tree_assign(tree, vecs))
    plv = np.array(probe_leaves(tree, jnp.asarray(q_np), probes))
    V = np.array(vecs, np.float32)
    out, pairs = [], 0
    for i in range(len(q_np)):
        cand = np.flatnonzero(np.isin(leaves, plv[i]))
        pairs += len(cand)
        d2 = ((V[cand] - q_np[i]) ** 2).sum(1)
        order = np.argsort(d2, kind="stable")
        out.append((cand[order][:k], np.sort(d2)[:k]))
    return out, pairs


# ---------------------------------------------------------------------------
# plan() heuristic + largest divisor
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 5000), cap=st.integers(1, 5000))
def test_largest_divisor_leq(n, cap):
    got = largest_divisor_leq(n, cap)
    # reference: the linear countdown this replaced
    want = next(b for b in range(min(cap, n), 0, -1) if n % b == 0)
    assert got == want
    assert n % got == 0 and got <= max(1, min(cap, n))


def test_plan_resolves_budgets_and_layouts():
    for layout in ("point_major", "query_routed", "auto"):
        p = plan(rows=100_000, n_leaves=1024, n_queries=512, n_shards=1,
                 k=10, layout=layout)
        assert p.layout in LAYOUTS
        if p.layout == "point_major":
            assert 100_000 % p.block_rows == 0
            assert p.q_cap >= 256
        else:
            assert p.q_tile >= 1 and p.p_cap >= 1
    # explicit layouts are honored
    assert plan(rows=8192, n_leaves=64, n_queries=32, n_shards=1, k=3,
                layout="point_major").layout == "point_major"
    assert plan(rows=8192, n_leaves=64, n_queries=32, n_shards=1, k=3,
                layout="query_routed").layout == "query_routed"
    # query_routed needs leaves to divide over shards; auto falls back
    assert plan(rows=8192, n_leaves=63, n_queries=32, n_shards=2, k=3,
                layout="auto").layout == "point_major"
    with pytest.raises(ValueError):
        plan(rows=8192, n_leaves=63, n_queries=32, n_shards=2, k=3,
             layout="query_routed")
    with pytest.raises(ValueError):
        plan(rows=8192, n_leaves=16, n_queries=32, n_shards=1, k=3, probes=17)


def test_search_plan_validation():
    with pytest.raises(ValueError):
        SearchPlan(layout="bogus", k=5)
    with pytest.raises(ValueError):
        SearchPlan(layout="point_major", k=0)
    with pytest.raises(ValueError):
        SearchPlan(layout="point_major", k=5, q_cap=64).resolved()  # no block_rows


# ---------------------------------------------------------------------------
# probe expansion
# ---------------------------------------------------------------------------


def test_probe_leaves_extend_hard_assignment(corpus):
    vecs, tree, mesh, index, q_np = corpus
    q = jnp.asarray(q_np)
    hard = np.array(tree_assign(tree, q))
    for probes in (1, 3):
        plv = np.array(probe_leaves(tree, q, probes))
        assert plv.shape == (len(q_np), probes)
        np.testing.assert_array_equal(plv[:, 0], hard)
        # probed leaves are distinct per query
        for i in range(len(q_np)):
            assert len(set(plv[i].tolist())) == probes


def test_build_lookup_flat_slots(corpus):
    vecs, tree, mesh, index, q_np = corpus
    q = jnp.asarray(q_np)
    for probes in (1, 4):
        lk = jax.jit(build_lookup, static_argnames=("probes",))(
            tree, q, probes=probes
        )
        qids = np.array(lk.qids)
        # qids are a permutation of the flat slot space
        np.testing.assert_array_equal(np.sort(qids),
                                      np.arange(len(q_np) * probes))
        # rows are leaf-sorted and offsets CSR-index them
        lv = np.array(lk.leaves)
        assert (np.diff(lv) >= 0).all()
        off = np.array(lk.offsets)
        for leaf in (0, tree.n_leaves // 2, tree.n_leaves - 1):
            assert (lv[off[leaf]:off[leaf + 1]] == leaf).all()


# ---------------------------------------------------------------------------
# executors vs oracle (probes=1 and multi-probe), both layouts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("probes", [1, 3])
def test_search_matches_multiprobe_oracle(corpus, layout, probes):
    vecs, tree, mesh, index, q_np = corpus
    k = 5
    res = batch_search(index, tree, jnp.asarray(q_np), k=k, mesh=mesh,
                       layout=layout, probes=probes)
    assert int(res.q_cap_overflow) == 0
    oracle, oracle_pairs = multiprobe_oracle(vecs, tree, q_np, probes, k)
    ids = np.array(res.ids)
    dists = np.array(res.dists)
    for i, (want_ids, want_d) in enumerate(oracle):
        got = ids[i][ids[i] >= 0]
        assert len(got) == min(k, len(want_ids))
        np.testing.assert_allclose(
            dists[i][: len(got)], want_d[: len(got)], rtol=1e-3, atol=2.0
        )
        assert set(got.tolist()) == set(want_ids[: len(got)].tolist())
    # pairs accounting is EXACT: every probed (point, query) pair counted
    assert float(res.pairs) == oracle_pairs


@pytest.mark.parametrize("probes", [1, 3])
def test_layouts_agree_exactly(corpus, probes):
    vecs, tree, mesh, index, q_np = corpus
    q = jnp.asarray(q_np)
    r_pm = batch_search(index, tree, q, k=4, mesh=mesh,
                        layout="point_major", probes=probes)
    r_qr = batch_search(index, tree, q, k=4, mesh=mesh,
                        layout="query_routed", probes=probes)
    np.testing.assert_array_equal(np.array(r_pm.ids), np.array(r_qr.ids))
    assert float(r_pm.pairs) == float(r_qr.pairs)


def test_multiprobe_improves_recall(corpus):
    """probes=3 strictly improves recall@1 over probes=1 against the
    global brute-force nearest neighbour, at a strictly higher pairs cost
    (the multi-probe recall/cost tradeoff, docs/engine.md)."""
    vecs, tree, mesh, index, q_np = corpus
    V = np.array(vecs, np.float32)
    gt = np.array([np.argmin(((V - qi) ** 2).sum(1)) for qi in q_np])
    recall, pairs = {}, {}
    for probes in (1, 3):
        res = batch_search(index, tree, jnp.asarray(q_np), k=1, mesh=mesh,
                           probes=probes)
        recall[probes] = float((np.array(res.ids[:, 0]) == gt).mean())
        pairs[probes] = float(res.pairs)
    assert recall[3] > recall[1], (recall, pairs)
    assert pairs[3] > pairs[1]


def test_self_queries_with_probes(corpus):
    vecs, tree, mesh, index, q_np = corpus
    res = batch_search(index, tree, vecs[:50], k=1, mesh=mesh, probes=2)
    np.testing.assert_array_equal(np.array(res.ids[:, 0]), np.arange(50))
    np.testing.assert_allclose(np.array(res.dists[:, 0]), 0.0, atol=1e-3)


# ---------------------------------------------------------------------------
# overflow accounting: zero when budgeted, counted when starved
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", LAYOUTS)
def test_overflow_zero_on_wellbudgeted(corpus, layout):
    vecs, tree, mesh, index, q_np = corpus
    res = batch_search(index, tree, jnp.asarray(q_np), k=3, mesh=mesh,
                       layout=layout, probes=2)
    assert int(res.q_cap_overflow) == 0


@pytest.mark.parametrize("layout", LAYOUTS)
def test_overflow_counted_on_starved_caps(corpus, layout):
    """A slab budget that is too small must be *counted*, never silent."""
    vecs, tree, mesh, index, q_np = corpus
    leaves = np.array(tree_assign(tree, vecs))
    dense_leaf = np.bincount(leaves).argmax()
    rows = np.flatnonzero(leaves == dense_leaf)[:64]
    assert len(rows) >= 32
    queries = vecs[rows]
    kw = dict(q_cap=8) if layout == "point_major" else dict(p_cap=8)
    res = batch_search(index, tree, queries, k=3, mesh=mesh, layout=layout,
                       **kw)
    assert int(res.q_cap_overflow) > 0


# ---------------------------------------------------------------------------
# dispatch substrate: capacity-padded sort round trip
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 200),
    n_buckets=st.integers(1, 12),
    capacity=st.integers(1, 48),
    seed=st.integers(0, 2**30),
)
def test_dispatch_combine_roundtrip_property(n, n_buckets, capacity, seed):
    key = jax.random.PRNGKey(seed)
    assign = jax.random.randint(key, (n,), 0, n_buckets)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, 3))
    d = make_dispatch(assign, n_buckets, capacity)
    y = combine_rows(d, dispatch_rows(d, x), fill=-7.0)
    fits = np.array(d.fits)
    np.testing.assert_allclose(np.array(y)[fits], np.array(x)[fits],
                               rtol=1e-6)
    assert (np.array(y)[~fits] == -7.0).all()
    # overflow is exactly the rows beyond capacity per bucket
    a = np.array(assign)
    want_drop = sum(
        max(0, int((a == b).sum()) - capacity) for b in range(n_buckets)
    )
    assert int(d.overflow) == want_drop == int((~fits).sum())
