"""Flash-attention kernel vs jnp oracle: GQA/MHA, windowed, history."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flashattn.ops import flash_attention
from repro.models import transformer as tfm


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,skv,hq,hkv,hd,win,tq,tkv",
    [
        (2, 64, 64, 4, 2, 16, -1, 32, 32),  # GQA causal
        (1, 32, 64, 6, 2, 8, 12, 16, 16),  # prefill-with-history + window
        (2, 128, 128, 8, 8, 32, -1, 64, 32),  # MHA
        (1, 64, 64, 4, 1, 16, 7, 64, 64),  # MQA, single tiles
    ],
)
def test_flash_matches_ref(b, sq, skv, hq, hkv, hd, win, tq, tkv, dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, sq, hq, hd)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, skv, hkv, hd)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, skv, hkv, hd)).astype(dtype)
    o_ref = flash_attention(q, k, v, window=win, impl="xla")
    o_pal = flash_attention(q, k, v, window=win, impl="pallas",
                            tile_q=tq, tile_kv=tkv)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.array(o_ref, np.float32), np.array(o_pal, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_matches_model_attend():
    """Oracle cross-check against the transformer's attend()."""
    B, S, Hq, Hkv, hd = 2, 32, 4, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(3), (B, S, Hq, hd))
    k = jax.random.normal(jax.random.PRNGKey(4), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(5), (B, S, Hkv, hd))
    pos = jnp.arange(S)
    want = tfm.attend(q, k, v, q_pos=pos, kv_pos=pos, window=jnp.int32(-1))
    got = flash_attention(q, k, v, impl="pallas", tile_q=16, tile_kv=16)
    np.testing.assert_allclose(
        np.array(want), np.array(got).reshape(B, S, Hq * hd),
        rtol=2e-4, atol=2e-4,
    )


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**30),
    hkv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 3]),
    win=st.sampled_from([-1, 5, 16]),
)
def test_flash_property_sweep(seed, hkv, g, win):
    B, S, hd = 1, 32, 8
    hq = hkv * g
    q = jax.random.normal(jax.random.PRNGKey(seed), (B, S, hq, hd))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, hkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (B, S, hkv, hd))
    o_ref = flash_attention(q, k, v, window=win, impl="xla")
    o_pal = flash_attention(q, k, v, window=win, impl="pallas",
                            tile_q=16, tile_kv=16)
    np.testing.assert_allclose(
        np.array(o_ref), np.array(o_pal), rtol=3e-4, atol=3e-4
    )
