"""The fused whole-shard scan (kernels/fusedscan) and its executor wiring.

Two parity contracts (docs/kernels.md):

  * kernel vs oracle — the Pallas kernel (interpret=True off-TPU) against
    the pure-jnp ref: **exact ids** always; dense distances ``allclose``
    (XLA fuses ``pn - 2*dot`` into FMA form the kernel doesn't use), ADC
    distances **bitwise** (same one-hot GEMM contraction order);
  * executor vs executor — ``impl="fused"`` (the pipelined double-buffered
    wave sweep off-TPU) is **bit-identical** to ``impl="xla"`` in ids and
    dists, across layouts, probes, and shard counts.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.index_build import build_index
from repro.core.tree import build_tree
from repro.data import synth
from repro.distributed.meshutil import local_mesh
from repro.index import Index, ShardedIndex
from repro.kernels.fusedscan.ops import fused_adc_topk, fused_topk

# ---------------------------------------------------------------------------
# kernel vs oracle (interpret-mode Pallas; small shapes — it's an eval loop)
# ---------------------------------------------------------------------------


def _dense_case(p, q, d, n_leaves, seed, dead_every=0):
    kk = jax.random.split(jax.random.PRNGKey(seed), 4)
    pts = jax.random.normal(kk[0], (p, d), jnp.float32)
    qrs = jax.random.normal(kk[1], (q, d), jnp.float32)
    plf = jax.random.randint(kk[2], (p,), 0, n_leaves)
    qlf = jax.random.randint(kk[3], (q,), 0, n_leaves)
    ids = jnp.arange(p, dtype=jnp.int32)
    if dead_every:
        ids = jnp.where(jnp.arange(p) % dead_every == 0, -1, ids)
    return pts, plf, ids, qrs, qlf


def _assert_dense_parity(ref, pal):
    """Dense contract: exact ids, allclose finite dists (2e-4), matching
    finite masks."""
    d_ref, i_ref = map(np.array, ref)
    d_pal, i_pal = map(np.array, pal)
    np.testing.assert_array_equal(i_ref, i_pal)
    finite = np.isfinite(d_ref)
    np.testing.assert_array_equal(finite, np.isfinite(d_pal))
    np.testing.assert_allclose(d_ref[finite], d_pal[finite],
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize(
    "p,q,d,k,n_leaves,tp,tq",
    [
        (256, 96, 16, 4, 6, 128, 64),  # exact tile grid
        (200, 70, 8, 8, 5, 128, 64),  # edge tiles on both axes
        (130, 33, 24, 5, 4, 128, 32),  # one-row overhang
        (64, 32, 8, 1, 2, 64, 32),  # k=1
    ],
)
def test_fused_topk_matches_ref(p, q, d, k, n_leaves, tp, tq):
    pts, plf, ids, qrs, qlf = _dense_case(p, q, d, n_leaves, seed=7,
                                          dead_every=9)
    ref = fused_topk(pts, plf, ids, qrs, qlf, k=k, impl="xla")
    pal = fused_topk(pts, plf, ids, qrs, qlf, k=k, impl="pallas",
                     tile_p=tp, tile_q=tq)
    _assert_dense_parity(ref, pal)


def test_fused_topk_duplicate_distances_stable_tiebreak():
    """Duplicated point rows produce exact distance ties; the selection
    contract (k smallest by (distance, shard row)) makes ids exact."""
    pts, plf, ids, qrs, qlf = _dense_case(96, 48, 8, 3, seed=11)
    pts = jnp.concatenate([pts, pts], axis=0)  # rows i and i+96 identical
    plf = jnp.concatenate([plf, plf])
    ids = jnp.arange(192, dtype=jnp.int32)
    ref = fused_topk(pts, plf, ids, qrs, qlf, k=6, impl="xla")
    pal = fused_topk(pts, plf, ids, qrs, qlf, k=6, impl="pallas",
                     tile_p=64, tile_q=32)
    _assert_dense_parity(ref, pal)
    # on an exact tie the earlier shard row must win: every selected id in
    # the duplicated half implies its twin (id - 96) was already taken
    i_pal = np.array(pal[1])
    for row in i_pal:
        for j, sel in enumerate(row):
            if sel >= 96:
                assert sel - 96 in row[:j]


def test_fused_topk_all_tombstoned_and_k_over_live():
    pts, plf, ids, qrs, qlf = _dense_case(64, 16, 8, 2, seed=3)
    dead = jnp.full_like(ids, -1)
    for impl in ("xla", "pallas"):
        d, i = fused_topk(pts, plf, dead, qrs, qlf, k=4, impl=impl)
        assert bool((np.array(i) == -1).all())
        assert bool(np.isinf(np.array(d)).all())
    # k far above the live rows of any leaf: the tail pads -1/inf and the
    # live prefix still matches the oracle exactly
    ref = fused_topk(pts, plf, ids, qrs, qlf, k=48, impl="xla")
    pal = fused_topk(pts, plf, ids, qrs, qlf, k=48, impl="pallas",
                     tile_p=64, tile_q=16)
    _assert_dense_parity(ref, pal)
    live = np.array(pal[1]) >= 0
    per_leaf = {lf: int((np.array(plf) == lf).sum())
                for lf in np.unique(np.array(qlf))}
    for qi, lf in enumerate(np.array(qlf)):
        assert live[qi].sum() == min(48, per_leaf[int(lf)])


@pytest.mark.parametrize("p,q,m,c,k", [(160, 48, 8, 16, 5), (64, 16, 4, 8, 3)])
def test_fused_adc_topk_bitwise(p, q, m, c, k):
    kk = jax.random.split(jax.random.PRNGKey(21), 4)
    codes = jax.random.randint(kk[0], (p, m), 0, c).astype(jnp.uint8)
    lut = jax.random.uniform(kk[1], (q, m, c), jnp.float32)
    plf = jax.random.randint(kk[2], (p,), 0, 4)
    qlf = jax.random.randint(kk[3], (q,), 0, 4)
    ids = jnp.where(jnp.arange(p) % 7 == 0, -1, jnp.arange(p)).astype(
        jnp.int32)
    d_ref, i_ref = fused_adc_topk(codes, plf, ids, lut, qlf, k=k, impl="xla")
    d_pal, i_pal = fused_adc_topk(codes, plf, ids, lut, qlf, k=k,
                                  impl="pallas", tile_p=64, tile_q=16)
    np.testing.assert_array_equal(np.array(i_ref), np.array(i_pal))
    # ADC sums LUT lanes in the same order on both paths: bitwise equal
    np.testing.assert_array_equal(np.array(d_ref), np.array(d_pal))


@settings(max_examples=10, deadline=None)
@given(
    p=st.integers(8, 96),
    q=st.integers(4, 48),
    k=st.sampled_from([1, 3, 5]),
    n_leaves=st.integers(1, 8),
    seed=st.integers(0, 2**30),
)
def test_fused_topk_property_sweep(p, q, k, n_leaves, seed):
    pts, plf, ids, qrs, qlf = _dense_case(p, q, 8, n_leaves, seed=seed,
                                          dead_every=5)
    ref = fused_topk(pts, plf, ids, qrs, qlf, k=k, impl="xla")
    pal = fused_topk(pts, plf, ids, qrs, qlf, k=k, impl="pallas",
                     tile_p=64, tile_q=32)
    _assert_dense_parity(ref, pal)


# ---------------------------------------------------------------------------
# executor vs executor: impl="fused" is bit-identical to impl="xla"
# ---------------------------------------------------------------------------

DIM = 24


@pytest.fixture(scope="module")
def corpus():
    vecs_np, _ = synth.sample_descriptors(3000, DIM, seed=0, n_centers=50)
    tree = build_tree(jnp.asarray(vecs_np), (8, 4),
                      key=jax.random.PRNGKey(1))
    mesh = local_mesh()
    q_np = np.array(vecs_np[:48]) + np.random.default_rng(2) \
        .standard_normal((48, DIM)).astype(np.float32)
    idx = Index.create(tree, None, mesh=mesh)
    idx.append(vecs_np[:1200])
    idx.append(vecs_np[1200:2100])
    idx.append(vecs_np[2100:])
    idx.enable_codes(m=8, bits=8, seed=0)
    idx.commit()
    return idx, q_np


@pytest.mark.parametrize("probes", [1, 2])
def test_fused_executor_bit_identical_dense(corpus, probes):
    idx, q_np = corpus
    ref = idx.search(q_np, k=5, probes=probes, layout="point_major",
                     impl="xla")
    got = idx.search(q_np, k=5, probes=probes, layout="point_major",
                     impl="fused")
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(got.dists),
                                  np.asarray(ref.dists))
    for shards in (2, 3):
        res = ShardedIndex(idx, n_shards=shards).search(
            q_np, k=5, probes=probes, layout="point_major", impl="fused"
        )
        np.testing.assert_array_equal(np.asarray(res.ids),
                                      np.asarray(ref.ids))
        np.testing.assert_array_equal(np.asarray(res.dists),
                                      np.asarray(ref.dists))


@pytest.mark.parametrize("probes", [1, 2])
def test_fused_executor_bit_identical_codes(corpus, probes):
    idx, q_np = corpus
    ref = idx.search(q_np, k=5, probes=probes, layout="scan_codes",
                     impl="xla")
    got = idx.search(q_np, k=5, probes=probes, layout="scan_codes",
                     impl="fused")
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(got.dists),
                                  np.asarray(ref.dists))
    res = ShardedIndex(idx, n_shards=2).search(
        q_np, k=5, probes=probes, layout="scan_codes", impl="fused"
    )
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(res.dists),
                                  np.asarray(ref.dists))


@settings(max_examples=8, deadline=None)
@given(
    probes=st.sampled_from([1, 2]),
    shards=st.sampled_from([1, 2, 3]),
    k=st.sampled_from([3, 7]),
)
def test_fused_bit_identity_property(corpus, probes, shards, k):
    """Hypothesis sweep over (probes, shards, k): fused == xla bit-for-bit.
    Shapes repeat across examples, so the executor cache keeps this
    cheap (zero recompiles after the first hit per shape)."""
    idx, q_np = corpus
    kw = dict(k=k, probes=probes, layout="point_major")
    if shards == 1:
        ref = idx.search(q_np, impl="xla", **kw)
        got = idx.search(q_np, impl="fused", **kw)
    else:
        sharded = ShardedIndex(idx, n_shards=shards)
        ref = sharded.search(q_np, impl="xla", **kw)
        got = sharded.search(q_np, impl="fused", **kw)
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(got.dists),
                                  np.asarray(ref.dists))


def test_forced_kernel_executor_paths(corpus, monkeypatch):
    """REPRO_FUSED_FORCE_KERNEL=1 builds the whole-shard Pallas kernel
    into the fused executor even off-TPU (interpret mode): dense results
    keep exact ids with allclose dists, ADC stays bitwise."""
    from repro.core.search import _cached_executor

    idx, q_np = corpus
    q = q_np[:16]  # interpret-mode kernel: keep the scan small
    ref_d = idx.search(q, k=4, layout="point_major", impl="xla")
    ref_c = idx.search(q, k=4, layout="scan_codes", impl="xla")
    monkeypatch.setenv("REPRO_FUSED_FORCE_KERNEL", "1")
    _cached_executor.cache_clear()  # executors bake the env choice in
    try:
        got_d = idx.search(q, k=4, layout="point_major", impl="fused")
        np.testing.assert_array_equal(np.asarray(got_d.ids),
                                      np.asarray(ref_d.ids))
        np.testing.assert_allclose(np.asarray(got_d.dists),
                                   np.asarray(ref_d.dists),
                                   rtol=2e-4, atol=2e-4)
        assert int(got_d.q_cap_overflow) == 0  # whole-shard: no slab cap
        got_c = idx.search(q, k=4, layout="scan_codes", impl="fused")
        np.testing.assert_array_equal(np.asarray(got_c.ids),
                                      np.asarray(ref_c.ids))
        np.testing.assert_array_equal(np.asarray(got_c.dists),
                                      np.asarray(ref_c.dists))
    finally:
        _cached_executor.cache_clear()  # don't leak kernel executors
