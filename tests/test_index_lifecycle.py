"""Segment lifecycle API: append/commit/delete/compact exactness against
one-shot builds, manifest crash-safety, and the legacy persistence shims."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.index_build import build_index
from repro.core.search import batch_search
from repro.core.tree import build_tree
from repro.data import synth
from repro.distributed.meshutil import local_mesh
from repro.index import Index, has_index
from repro.index import manifest as manifest_lib

DIM = 24
N = 3000
SPLIT = 1300


@pytest.fixture(scope="module")
def corpus():
    vecs_np, _ = synth.sample_descriptors(N, DIM, seed=0, n_centers=50)
    tree = build_tree(jnp.asarray(vecs_np), (8, 4), key=jax.random.PRNGKey(1))
    mesh = local_mesh()
    oneshot = build_index(jnp.asarray(vecs_np), tree, mesh,
                          wire_dtype=jnp.float32)
    q_np = vecs_np[:80] + np.random.default_rng(2).standard_normal(
        (80, DIM)
    ).astype(np.float32)
    return vecs_np, tree, mesh, oneshot, q_np


def _grow(corpus, directory):
    """create -> append x2 -> commit: the canonical grown index."""
    vecs_np, tree, mesh, _, _ = corpus
    idx = Index.create(tree, directory, mesh=mesh)
    idx.append(vecs_np[:SPLIT])
    idx.append(vecs_np[SPLIT:])
    idx.commit()
    return idx


# ---------------------------------------------------------------------------
# the acceptance invariant: N segments == one-shot build, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["point_major", "query_routed"])
def test_append_search_bit_identical_to_oneshot(corpus, tmp_path, layout):
    vecs_np, tree, mesh, oneshot, q_np = corpus
    idx = _grow(corpus, str(tmp_path / "idx"))
    assert idx.n_segments == 2 and idx.rows == N
    for probes in (1, 2):
        res = idx.search(q_np, k=5, layout=layout, probes=probes, q_cap=512)
        ref = batch_search(oneshot, tree, jnp.asarray(q_np), k=5, mesh=mesh,
                           layout=layout, probes=probes, q_cap=512)
        assert int(res.q_cap_overflow) == 0 == int(ref.q_cap_overflow)
        np.testing.assert_array_equal(np.asarray(res.ids),
                                      np.asarray(ref.ids))
        np.testing.assert_array_equal(np.asarray(res.dists),
                                      np.asarray(ref.dists))


def test_open_restores_committed_state(corpus, tmp_path):
    vecs_np, tree, mesh, oneshot, q_np = corpus
    d = str(tmp_path / "idx")
    _grow(corpus, d)
    idx = Index.open(d, mesh=mesh)
    assert idx.n_segments == 2 and idx.rows == N and idx.version == 1
    res = idx.search(q_np, k=5, layout="point_major", q_cap=512)
    ref = batch_search(oneshot, tree, jnp.asarray(q_np), k=5, mesh=mesh,
                       q_cap=512)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))


def test_compact_matches_oneshot_arrays(corpus, tmp_path):
    """After compact() the segment is the one-shot index — arrays and all,
    not just search results."""
    vecs_np, tree, mesh, oneshot, q_np = corpus
    idx = _grow(corpus, str(tmp_path / "idx"))
    before = idx.search(q_np, k=5, layout="point_major", q_cap=512)
    name = idx.compact()
    assert idx.n_segments == 1 and idx.rows == N
    seg = idx.segments[0]
    assert seg.name == name
    for a, b in (
        (seg.index.vecs, oneshot.vecs), (seg.index.ids, oneshot.ids),
        (seg.index.leaves, oneshot.leaves),
        (seg.index.offsets, oneshot.offsets),
        (seg.index.n_valid, oneshot.n_valid),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    after = idx.search(q_np, k=5, layout="point_major", q_cap=512)
    np.testing.assert_array_equal(np.asarray(before.ids),
                                  np.asarray(after.ids))
    np.testing.assert_array_equal(np.asarray(before.dists),
                                  np.asarray(after.dists))
    # old segment checkpoints were garbage-collected after the bump
    seg_dir = tmp_path / "idx" / manifest_lib.SEGMENTS_SUBDIR
    assert sorted(os.listdir(seg_dir)) == [name]


@pytest.mark.parametrize("layout", ["point_major", "query_routed"])
def test_delete_matches_rebuild_without_rows(corpus, tmp_path, layout):
    vecs_np, tree, mesh, _, q_np = corpus
    idx = _grow(corpus, str(tmp_path / "idx"))
    dead = np.concatenate([np.arange(7), [SPLIT - 1, SPLIT, N - 1]])
    assert idx.delete(dead) == len(dead)
    assert idx.delete(dead) == 0  # idempotent: already tombstoned
    assert idx.delete([10**6]) == 0  # absent ids are not recorded
    assert idx.rows == N - len(dead)
    keep = ~np.isin(np.arange(N), dead)
    rebuilt = build_index(
        jnp.asarray(vecs_np[keep]), tree, mesh,
        ids=jnp.asarray(np.flatnonzero(keep).astype(np.int32)),
        wire_dtype=jnp.float32,
    )
    ref = batch_search(rebuilt, tree, jnp.asarray(q_np), k=5, mesh=mesh,
                       layout=layout, q_cap=512)
    res = idx.search(q_np, k=5, layout=layout, q_cap=512)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(res.dists),
                                  np.asarray(ref.dists))
    # compaction drops the tombstones physically, results unchanged
    idx.commit()
    idx.compact()
    assert idx.rows == N - len(dead) and len(idx.tombstones) == 0
    res2 = idx.search(q_np, k=5, layout=layout, q_cap=512)
    np.testing.assert_array_equal(np.asarray(res2.ids), np.asarray(ref.ids))


# ---------------------------------------------------------------------------
# crash-safety: visibility is exactly the last committed manifest
# ---------------------------------------------------------------------------


def test_crash_between_append_and_commit_is_invisible(corpus, tmp_path):
    vecs_np, tree, mesh, _, q_np = corpus
    d = str(tmp_path / "idx")
    idx = Index.create(tree, d, mesh=mesh)
    idx.append(vecs_np[:SPLIT])
    v1 = idx.commit()
    # "crash": a second handle appends durably but never commits
    dying = Index.open(d, mesh=mesh)
    orphan = dying.append(vecs_np[SPLIT:])
    del dying
    seg_dir = os.path.join(d, manifest_lib.SEGMENTS_SUBDIR)
    assert orphan in os.listdir(seg_dir)  # bytes on disk...
    reopened = Index.open(d, mesh=mesh)
    assert reopened.version == v1
    assert reopened.n_segments == 1  # ...but invisible without a manifest
    assert reopened.rows == SPLIT
    # a retried append never collides with the orphan's reserved name
    retried = reopened.append(vecs_np[SPLIT:])
    assert retried != orphan
    reopened.commit()
    final = Index.open(d, mesh=mesh)
    assert final.n_segments == 2 and final.rows == N


def test_failed_commit_stays_staged_and_retries(corpus, tmp_path, monkeypatch):
    """A commit whose manifest write fails must leave the handle staged so
    a retried commit() re-attempts publication instead of no-opping."""
    vecs_np, tree, mesh, _, _ = corpus
    d = str(tmp_path / "idx")
    idx = Index.create(tree, d, mesh=mesh)
    idx.append(vecs_np[:SPLIT])

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(manifest_lib, "write", boom)
    with pytest.raises(OSError):
        idx.commit()
    monkeypatch.undo()
    assert idx.version == 0 and idx.staged_segments  # still staged
    v = idx.commit()  # the retry actually publishes
    assert v == 1
    assert Index.open(d, mesh=mesh).rows == SPLIT


def test_failed_compact_preserves_tombstones(corpus, tmp_path, monkeypatch):
    """An exception during the compaction rebuild must not resurrect
    deleted rows — segments and tombstones stay exactly as committed."""
    import repro.index.lifecycle as lifecycle_mod

    vecs_np, tree, mesh, _, q_np = corpus
    idx = _grow(corpus, str(tmp_path / "idx"))
    idx.delete(np.arange(5))
    idx.commit()

    def boom(*a, **kw):
        raise RuntimeError("device OOM")

    monkeypatch.setattr(lifecycle_mod, "build_index", boom)
    with pytest.raises(RuntimeError):
        idx.compact()
    monkeypatch.undo()
    assert len(idx.tombstones) == 5 and idx.n_segments == 2
    ids = np.asarray(idx.search(q_np[:8], k=5, q_cap=512).ids)
    assert not np.isin(ids, np.arange(5)).any()  # still deleted
    idx.compact()  # and the retry succeeds
    assert idx.rows == N - 5


def test_concurrent_commit_loses_loudly_not_silently(corpus, tmp_path):
    """Two handles racing to publish the same next manifest version: the
    loser gets FileExistsError instead of silently overwriting the
    winner's manifest (which would orphan its committed segments)."""
    vecs_np, tree, mesh, _, _ = corpus
    d = str(tmp_path / "idx")
    Index.create(tree, d, mesh=mesh)
    a = Index.open(d, mesh=mesh)
    b = Index.open(d, mesh=mesh)
    a.append(vecs_np[:100])
    b.append(vecs_np[100:200])
    assert a.commit() == 1
    with pytest.raises(FileExistsError, match="committed concurrently"):
        b.commit()
    # the winner's data is intact; the loser stays staged for a reopen
    assert Index.open(d, mesh=mesh).rows == 100
    assert b.staged_segments


def test_launch_index_rerun_resumes_not_duplicates(tmp_path, monkeypatch):
    """Re-running a --commit-every job over the same store resumes from
    the ingest cursor instead of appending every block again."""
    from repro.launch import index as index_cli

    d = str(tmp_path / "resume")
    args = ["--rows", "4000", "--dim", "16", "--block-rows", "1000",
            "--fanout", "4", "4", "--tree-sample", "1024",
            "--commit-every", "1", "--index-dir", d]
    # crash the first run after 2 committed blocks
    from repro.distributed import wavescheduler as ws

    real_run = ws.WaveScheduler.run

    def crash_after_two(self, waves, **kw):
        return real_run(self, list(waves)[:2], **kw)

    monkeypatch.setattr(ws.WaveScheduler, "run", crash_after_two)
    with pytest.raises(AssertionError):  # job dies before finishing
        index_cli.main(args)
    monkeypatch.undo()
    assert Index.open(d).rows == 2000  # blocks 0-1 committed
    assert index_cli.main(args) == 0  # rerun resumes at block 2
    idx = Index.open(d)
    assert idx.rows == 4000  # nothing duplicated
    ids = np.sort(np.concatenate(
        [s.host_ids()[s.host_ids() >= 0] for s in idx.segments]
    ))
    np.testing.assert_array_equal(ids, np.arange(4000))


def test_tombstone_publication_is_exclusive(tmp_path):
    """The loser of a commit race must not clobber the winner's published
    tombstone file; only a same-handle retry (identical bytes) passes."""
    d = str(tmp_path)
    rel = manifest_lib.write_tombstones(d, 2, np.array([1, 2]))
    assert manifest_lib.write_tombstones(d, 2, np.array([1, 2])) == rel
    with pytest.raises(FileExistsError, match="different contents"):
        manifest_lib.write_tombstones(d, 2, np.array([3]))
    np.testing.assert_array_equal(
        manifest_lib.read_tombstones(d, rel), [1, 2]
    )


def test_legacy_format_dir_fails_actionably(corpus, tmp_path):
    vecs_np, tree, mesh, _, _ = corpus
    d = tmp_path / "legacy"
    (d / "index_ckpt").mkdir(parents=True)
    assert not has_index(str(d))
    with pytest.raises(FileNotFoundError, match="pre-segment-format"):
        Index.open(str(d), mesh=mesh)


def test_double_commit_is_idempotent(corpus, tmp_path):
    d = str(tmp_path / "idx")
    idx = _grow(corpus, d)
    v = idx.version
    files = sorted(os.listdir(d))
    assert idx.commit() == v
    assert idx.commit() == v
    assert sorted(os.listdir(d)) == files  # no new manifest written
    assert manifest_lib.list_versions(d) == [0, v]


def test_create_open_guards(corpus, tmp_path):
    vecs_np, tree, mesh, _, q_np = corpus
    d = str(tmp_path / "idx")
    assert not has_index(d)
    idx = Index.create(tree, d, mesh=mesh)
    assert has_index(d)
    with pytest.raises(FileExistsError):
        Index.create(tree, d, mesh=mesh)
    with pytest.raises(FileNotFoundError):
        Index.open(str(tmp_path / "nope"), mesh=mesh)
    # an empty index searches to no-neighbour sentinels
    res = idx.search(q_np[:4], k=3)
    assert (np.asarray(res.ids) == -1).all()
    assert np.isinf(np.asarray(res.dists)).all()


def test_append_id_validation(corpus, tmp_path):
    vecs_np, tree, mesh, _, _ = corpus
    idx = Index.create(tree, None, mesh=mesh)
    idx.append(vecs_np[:100])  # auto ids 0..99
    with pytest.raises(ValueError, match="collide"):
        idx.append(vecs_np[100:200], ids=np.arange(50, 150))
    with pytest.raises(ValueError, match="duplicate"):
        idx.append(vecs_np[100:200], ids=np.zeros(100, np.int64) + 500)
    with pytest.raises(ValueError, match="non-negative"):
        idx.append(vecs_np[100:200], ids=np.arange(-1, 99))
    idx.append(vecs_np[100:200])  # auto ids continue at 100
    assert idx.next_id == 200


# ---------------------------------------------------------------------------
# legacy shims: persist.save_index/load_index keep working (deprecated)
# ---------------------------------------------------------------------------


def test_persist_shims_roundtrip_and_refuse_grown(corpus, tmp_path):
    from repro.serving import persist

    vecs_np, tree, mesh, oneshot, _ = corpus
    d = str(tmp_path / "shim")
    with pytest.warns(DeprecationWarning):
        persist.save_index(d, oneshot, tree, extra={"images": 9})
    with pytest.warns(DeprecationWarning):
        r_index, r_tree, meta = persist.load_index(d, mesh)
    assert meta["images"] == 9 and meta["n_leaves"] == oneshot.n_leaves
    np.testing.assert_array_equal(np.asarray(r_index.ids),
                                  np.asarray(oneshot.ids))
    # a grown index has no single-DistributedIndex representation
    grown = str(tmp_path / "grown")
    _grow(corpus, grown)
    with pytest.warns(DeprecationWarning), pytest.raises(ValueError):
        persist.load_index(grown, mesh)


# ---------------------------------------------------------------------------
# serving a grown index: SearchSession from an Index
# ---------------------------------------------------------------------------


def test_session_over_grown_index_matches_facade_search(corpus, tmp_path):
    from repro.serving import SearchSession

    vecs_np, tree, mesh, _, q_np = corpus
    idx = _grow(corpus, str(tmp_path / "idx"))
    s = SearchSession(idx, k=5, layout="point_major", probes=2,
                      buckets=(32, 96))
    warmed_ms = s.warmup()
    assert warmed_ms > 0 and s.recompiles() == len(s.buckets)
    for n in (1, 31, 50, 96):
        ids, dists = s.search(q_np[:n])
        rt = s._runtimes[96 if n > 32 else 32]
        # same per-segment plan budgets the session compiled with
        direct = idx.search(
            q_np[:n], k=5, layout="point_major", probes=2,
            block_rows=rt.plan.block_rows, q_cap=rt.plan.q_cap,
        )
        np.testing.assert_array_equal(ids, np.asarray(direct.ids))
        np.testing.assert_array_equal(dists, np.asarray(direct.dists))
    assert s.steady_state_recompiles() == 0
    # deletes flow into serving after a refresh + rewarm
    idx.delete(np.arange(5))
    s.refresh()
    s.warmup()
    ids, _ = s.search(q_np[:8])
    assert not np.isin(ids, np.arange(5)).any()
    assert s.steady_state_recompiles() == 0


def test_load_or_build_rebuilds_over_crashed_empty_index(corpus, tmp_path):
    """A crash between Index.create and the first commit leaves a
    committed-empty index; load_or_build must fall back to building, not
    serve (or crash on) an index with no segments."""
    from repro.serving import SearchSession

    vecs_np, tree, mesh, oneshot, _ = corpus
    d = str(tmp_path / "crashed")
    Index.create(tree, d, mesh=mesh)  # "crash" before any append/commit
    assert has_index(d)
    calls = []

    def build_fn():
        calls.append(1)
        return oneshot, tree, {"images": 1}

    s, meta = SearchSession.load_or_build(d, build_fn=build_fn, mesh=mesh,
                                          k=3, buckets=(32,))
    assert calls == [1] and meta["restored"] is False
    assert Index.open(d, mesh=mesh).n_segments == 1
    # and the repaired index restores normally afterwards
    s2, meta2 = SearchSession.load_or_build(d, build_fn=build_fn, mesh=mesh,
                                            k=3, buckets=(32,))
    assert calls == [1] and meta2["restored"] is True


def test_refresh_drops_stale_cache_slabs(corpus, tmp_path):
    """A hot-leaf cache slab admitted before a delete must not keep
    serving the deleted row after session.refresh()."""
    from repro.serving import SearchSession

    vecs_np, tree, mesh, _, q_np = corpus
    idx = _grow(corpus, str(tmp_path / "idx"))
    s = SearchSession(idx, k=3, layout="point_major", buckets=(32,),
                      cache_leaves=tree.n_leaves, cache_admit_after=1)
    s.warmup()
    q = q_np[:8]
    s.search(q)  # admit + memoise
    hit = s.cache.try_serve(q, 3)
    assert hit is not None  # repeat is cache-servable
    victim = int(hit[0][0, 0])
    assert victim >= 0
    idx.delete([victim])
    s.refresh()
    s.warmup()
    assert s.cache.try_serve(q, 3) is None  # stale slabs dropped
    s.search(q)  # re-admit post-delete
    hit2 = s.cache.try_serve(q, 3)
    assert hit2 is not None and victim not in hit2[0]


def test_session_legacy_pair_still_constructs(corpus):
    from repro.serving import SearchSession

    vecs_np, tree, mesh, oneshot, q_np = corpus
    s = SearchSession(oneshot, tree, mesh, k=3, layout="point_major",
                      buckets=(32,))
    s.warmup()
    ids, _ = s.search(q_np[:8])
    ref = batch_search(oneshot, tree, jnp.asarray(q_np[:8]), k=3, mesh=mesh,
                       layout="point_major",
                       block_rows=s._runtimes[32].plan.block_rows,
                       q_cap=s._runtimes[32].plan.q_cap)
    np.testing.assert_array_equal(ids, np.asarray(ref.ids))
    with pytest.raises(TypeError):
        SearchSession(oneshot)  # legacy pair without its tree


# ---------------------------------------------------------------------------
# launch/index.py: historical flags keep working over the facade
# ---------------------------------------------------------------------------


def test_launch_index_cli_legacy_flags(tmp_path):
    from repro.launch import index as index_cli

    rc = index_cli.main([
        "--rows", "4000", "--dim", "16", "--block-rows", "1000",
        "--fanout", "4", "4", "--tree-sample", "1024",
        "--inject-failures", "--verify-queries", "16", "--probes", "2",
        "--index-dir", str(tmp_path / "cli"), "--compact",
    ])
    assert rc == 0
    idx = Index.open(str(tmp_path / "cli"))
    assert idx.rows == 4000 and idx.n_segments == 1


def test_grow_then_serve_roundtrip(tmp_path):
    """An --index-dir grown by repro.launch.index (no corpus/ store) is
    servable: the trace generator reads query rows from the segments."""
    from repro.launch import index as index_cli, serve as serve_cli

    d = str(tmp_path / "grown")
    assert index_cli.main([
        "--rows", "4000", "--dim", "16", "--block-rows", "2000",
        "--fanout", "4", "4", "--tree-sample", "1024", "--index-dir", d,
    ]) == 0
    rc = serve_cli.main([
        "--index-dir", d, "--dim", "16", "--desc-per-image", "20",
        "--trace", "uniform", "--requests", "20", "--buckets", "64",
        "--no-recall",
    ])
    assert rc == 0


def test_read_rows_by_descriptor_id(corpus, tmp_path):
    vecs_np, tree, mesh, _, _ = corpus
    idx = _grow(corpus, str(tmp_path / "idx"))
    rows = np.array([2999, 0, 1300, 1299, 0])  # cross-segment, dups, order
    got = idx.read_rows(rows)
    np.testing.assert_array_equal(got, vecs_np[rows])
    with pytest.raises(IndexError, match="not in the index"):
        idx.read_rows([N + 5])
    # tombstoned ids read as missing immediately, not only after compact
    idx.delete([1300])
    with pytest.raises(IndexError, match="absent or deleted"):
        idx.read_rows(rows)
    with pytest.raises(ValueError, match="int32"):
        idx.append(vecs_np[:4], ids=np.array([N, N + 1, N + 2, 2**31]))
