"""End-to-end index + search exactness vs brute-force oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.index_build import build_index
from repro.core.search import batch_search
from repro.core.tree import build_tree, tree_assign
from repro.data import synth
from repro.distributed.meshutil import local_mesh


@pytest.fixture(scope="module")
def corpus():
    vecs_np, _ = synth.sample_descriptors(3000, 24, seed=0, n_centers=50)
    vecs = jnp.asarray(vecs_np)
    tree = build_tree(vecs, (8, 4), key=jax.random.PRNGKey(1))
    mesh = local_mesh()
    index = build_index(vecs, tree, mesh, wire_dtype=jnp.float32)
    return vecs, tree, mesh, index


def in_leaf_oracle(vecs, tree, queries, k):
    leaves = np.array(tree_assign(tree, vecs))
    qleaves = np.array(tree_assign(tree, jnp.asarray(queries)))
    V = np.array(vecs, np.float32)
    out = []
    for i in range(len(queries)):
        cand = np.flatnonzero(leaves == qleaves[i])
        d2 = ((V[cand] - queries[i]) ** 2).sum(1)
        order = np.argsort(d2)
        out.append((cand[order][:k], np.sort(d2)[:k]))
    return out


def test_index_completeness(corpus):
    vecs, tree, mesh, index = corpus
    assert int(index.overflow) == 0
    ids = np.array(index.ids)
    valid = ids[ids >= 0]
    assert len(valid) == vecs.shape[0]
    assert len(np.unique(valid)) == vecs.shape[0], "every descriptor indexed once"
    # leaf-sorted within shards, and leaves agree with direct assignment
    leaves = np.array(index.leaves)
    direct = np.array(tree_assign(tree, vecs))
    np.testing.assert_array_equal(leaves[ids >= 0][np.argsort(valid)], direct)


def test_search_exact_within_leaves(corpus):
    vecs, tree, mesh, index = corpus
    q_np = np.array(vecs[:80]) + np.random.default_rng(2).standard_normal(
        (80, vecs.shape[1])
    ).astype(np.float32)
    res = batch_search(index, tree, jnp.asarray(q_np), k=5, mesh=mesh, q_cap=512)
    assert int(res.q_cap_overflow) == 0
    oracle = in_leaf_oracle(vecs, tree, q_np, 5)
    ids = np.array(res.ids)
    dists = np.array(res.dists)
    for i, (want_ids, want_d) in enumerate(oracle):
        got = ids[i][ids[i] >= 0]
        assert len(got) == min(5, len(want_ids))
        # ||p||^2 - 2pq + ||q||^2 in fp32 cancels ~1 ulp of the squared
        # norms (values up to ~1e6 for byte descriptors) vs the (p-q)^2
        # oracle: allow that absolute slack
        np.testing.assert_allclose(
            dists[i][: len(got)], want_d[: len(got)], rtol=1e-3, atol=2.0
        )
        assert set(got.tolist()) == set(want_ids[: len(got)].tolist())


def test_search_q_cap_overflow_detected(corpus):
    """A slab budget that is too small must be *counted*, never silent."""
    vecs, tree, mesh, index = corpus
    # all queries in one leaf: pick the densest leaf's members
    leaves = np.array(tree_assign(tree, vecs))
    dense_leaf = np.bincount(leaves).argmax()
    rows = np.flatnonzero(leaves == dense_leaf)[:64]
    assert len(rows) >= 32
    queries = vecs[rows]
    res = batch_search(index, tree, queries, k=3, mesh=mesh, q_cap=8)
    assert int(res.q_cap_overflow) > 0


def test_search_self_query_finds_itself(corpus):
    vecs, tree, mesh, index = corpus
    res = batch_search(index, tree, vecs[:50], k=1, mesh=mesh, q_cap=512)
    np.testing.assert_array_equal(np.array(res.ids[:, 0]), np.arange(50))
    np.testing.assert_allclose(np.array(res.dists[:, 0]), 0.0, atol=1e-3)


def test_bf16_wire_compression_close(corpus):
    """The paper's map-output-compression analog: bf16 wire loses only
    rounding-level accuracy (top-1 overlap >= 95%)."""
    vecs, tree, mesh, _ = corpus
    idx16 = build_index(vecs, tree, mesh, wire_dtype=jnp.bfloat16)
    q = vecs[:100] + 0.5
    r32 = batch_search(
        build_index(vecs, tree, mesh, wire_dtype=jnp.float32),
        tree, q, k=1, mesh=mesh, q_cap=512,
    )
    r16 = batch_search(idx16, tree, q, k=1, mesh=mesh, q_cap=512)
    agree = (np.array(r32.ids[:, 0]) == np.array(r16.ids[:, 0])).mean()
    assert agree >= 0.95, f"bf16 wire top-1 agreement {agree}"


def test_unpadded_row_counts():
    """Non-divisible row counts are padded and padding never surfaces."""
    vecs = jax.random.normal(jax.random.PRNGKey(3), (1003, 8))
    tree = build_tree(vecs, (4, 4), key=jax.random.PRNGKey(4))
    mesh = local_mesh()
    index = build_index(vecs, tree, mesh, wire_dtype=jnp.float32)
    ids = np.array(index.ids)
    assert (ids < 1003).all()
    assert len(np.unique(ids[ids >= 0])) == 1003
    res = batch_search(index, tree, vecs[:7], k=2, mesh=mesh, q_cap=256)
    assert (np.array(res.ids) < 1003).all()
    np.testing.assert_array_equal(np.array(res.ids[:, 0]), np.arange(7))
