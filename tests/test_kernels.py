"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes/dtypes (hypothesis + explicit grids)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.l2nn.ops import l2_nearest
from repro.kernels.l2nn.ref import l2_nearest_ref
from repro.kernels.l2topk.ops import l2_topk
from repro.kernels.l2topk.ref import l2_topk_ref


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# l2nn
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "n,c,d,tn,tc",
    [
        (128, 64, 16, 64, 32),
        (200, 70, 8, 128, 64),  # padding on both axes
        (64, 512, 128, 64, 128),  # SIFT dim, many centroids
        (32, 8, 4, 32, 8),
    ],
)
def test_l2nn_matches_ref(n, c, d, tn, tc, dtype):
    x = _rand(1, (n, d), dtype)
    cen = _rand(2, (c, d), dtype)
    i_ref, d_ref = l2_nearest(x, cen, impl="xla")
    i_pal, d_pal = l2_nearest(x, cen, impl="pallas", tile_n=tn, tile_c=tc)
    np.testing.assert_array_equal(np.array(i_ref), np.array(i_pal))
    np.testing.assert_allclose(
        np.array(d_ref), np.array(d_pal), rtol=2e-5, atol=2e-5
    )


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 150),
    c=st.integers(2, 90),
    d=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**30),
)
def test_l2nn_property_sweep(n, c, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    cen = jax.random.normal(jax.random.PRNGKey(seed + 1), (c, d))
    i_pal, d_pal = l2_nearest(x, cen, impl="pallas", tile_n=64, tile_c=32)
    # oracle in numpy, full distances
    d2 = ((np.array(x)[:, None] - np.array(cen)[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(np.array(i_pal), d2.argmin(1))
    np.testing.assert_allclose(np.array(d_pal), d2.min(1), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# l2topk
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "p,q,d,k,n_leaves",
    [
        (256, 128, 16, 4, 8),
        (300, 100, 8, 8, 5),  # padded tiles
        (128, 64, 128, 16, 3),  # SIFT dim
        (64, 32, 4, 1, 2),  # k=1
    ],
)
def test_l2topk_matches_ref(p, q, d, k, n_leaves, dtype):
    pts = _rand(3, (p, d), dtype)
    qrs = _rand(4, (q, d), dtype)
    plf = jax.random.randint(jax.random.PRNGKey(5), (p,), 0, n_leaves)
    qlf = jax.random.randint(jax.random.PRNGKey(6), (q,), 0, n_leaves)
    d_ref, i_ref = l2_topk(pts, plf, qrs, qlf, k=k, impl="xla")
    d_pal, i_pal = l2_topk(pts, plf, qrs, qlf, k=k, impl="pallas",
                           tile_p=128, tile_q=64)
    d_ref, i_ref, d_pal, i_pal = map(np.array, (d_ref, i_ref, d_pal, i_pal))
    finite = np.isfinite(d_ref)
    np.testing.assert_array_equal(finite, np.isfinite(d_pal))
    np.testing.assert_allclose(d_ref[finite], d_pal[finite], rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(i_ref, i_pal)


def test_l2topk_no_matches_gives_invalid():
    pts = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
    qrs = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    plf = jnp.zeros((64,), jnp.int32)
    qlf = jnp.ones((32,), jnp.int32)  # disjoint leaves: no matches at all
    for impl in ("xla", "pallas"):
        d, i = l2_topk(pts, plf, qrs, qlf, k=3, impl=impl)
        assert bool((np.array(i) == -1).all())
        assert bool(np.isinf(np.array(d)).all())


@settings(max_examples=20, deadline=None)
@given(
    p=st.integers(8, 200),
    q=st.integers(4, 100),
    k=st.sampled_from([1, 3, 5]),
    n_leaves=st.integers(1, 12),
    seed=st.integers(0, 2**30),
)
def test_l2topk_property_sweep(p, q, k, n_leaves, seed):
    d = 8
    pts = jax.random.normal(jax.random.PRNGKey(seed), (p, d))
    qrs = jax.random.normal(jax.random.PRNGKey(seed + 1), (q, d))
    plf = jax.random.randint(jax.random.PRNGKey(seed + 2), (p,), 0, n_leaves)
    qlf = jax.random.randint(jax.random.PRNGKey(seed + 3), (q,), 0, n_leaves)
    d_pal, i_pal = l2_topk(pts, plf, qrs, qlf, k=k, impl="pallas",
                           tile_p=64, tile_q=32)
    d_pal, i_pal = np.array(d_pal), np.array(i_pal)
    # numpy oracle
    P, Q = np.array(pts), np.array(qrs)
    pl, ql = np.array(plf), np.array(qlf)
    pn = (P * P).sum(1)
    for qi in range(q):
        cand = np.flatnonzero(pl == ql[qi])
        partial = pn[cand] - 2 * P[cand] @ Q[qi]
        order = cand[np.argsort(partial)][:k]
        got = i_pal[qi][i_pal[qi] >= 0]
        assert len(got) == min(k, len(cand))
        # distances must match the oracle's sorted top-k (ids may tie-swap)
        np.testing.assert_allclose(
            d_pal[qi][: len(got)],
            np.sort(partial)[: len(got)],
            rtol=1e-4,
            atol=1e-4,
        )
        assert set(got.tolist()) <= set(cand.tolist())
