"""Model zoo behaviour: LM consistency, masking, MoE, GIN, recsys oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import gnn, recsys
from repro.models import transformer as tfm
from repro.models.module import init_params


@pytest.fixture(scope="module")
def lm():
    cfg = tfm.TransformerConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=64, dtype="float32",
    )
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    return cfg, params


def test_prefill_decode_match_forward(lm):
    cfg, params = lm
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 64)
    logits, _ = jax.jit(lambda p, t: tfm.forward(p, cfg, t))(params, toks)
    plogits, cache = jax.jit(lambda p, t: tfm.prefill(p, cfg, t, 16))(params, toks)
    np.testing.assert_allclose(np.array(plogits), np.array(logits), atol=1e-4)
    nxt = jnp.argmax(plogits[:, -1:], -1).astype(jnp.int32)
    dl, _ = jax.jit(
        lambda p, t, c: tfm.decode_step(p, cfg, t, c, jnp.int32(12))
    )(params, nxt, cache)
    full, _ = jax.jit(lambda p, t: tfm.forward(p, cfg, t))(
        params, jnp.concatenate([toks, nxt], 1)
    )
    np.testing.assert_allclose(
        np.array(dl[:, 0]), np.array(full[:, -1]), atol=1e-3
    )


def test_sliding_window_masks_past(lm):
    """With window w, positions >= w back must not influence the output."""
    cfg0, _ = lm
    cfg = tfm.TransformerConfig(
        name="w", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
        d_ff=64, vocab_size=64, dtype="float32", window=3,
    )
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(2))
    t1 = jax.random.randint(jax.random.PRNGKey(3), (1, 10), 0, 64)
    t2 = t1.at[0, 0].set((t1[0, 0] + 17) % 64)  # perturb a distant token
    l1, _ = tfm.forward(params, cfg, t1)
    l2, _ = tfm.forward(params, cfg, t2)
    # last position attends to [7,8,9] only -> identical logits
    np.testing.assert_allclose(
        np.array(l1[0, -1]), np.array(l2[0, -1]), atol=1e-5
    )
    # but an in-window perturbation must change it
    t3 = t1.at[0, 9].set((t1[0, 9] + 17) % 64)
    l3, _ = tfm.forward(params, cfg, t3)
    assert np.abs(np.array(l3[0, -1]) - np.array(l1[0, -1])).max() > 1e-4


def test_causality(lm):
    cfg, params = lm
    t1 = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0, 64)
    t2 = t1.at[0, 5].set((t1[0, 5] + 3) % 64)
    l1, _ = tfm.forward(params, cfg, t1)
    l2, _ = tfm.forward(params, cfg, t2)
    np.testing.assert_allclose(
        np.array(l1[0, :5]), np.array(l2[0, :5]), atol=1e-5
    )


def test_moe_drops_counted():
    cfg = tfm.TransformerConfig(
        name="m", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2, head_dim=8,
        d_ff=32, vocab_size=32, dtype="float32",
        moe=tfm.MoEConfig(n_experts=4, top_k=2, d_ff=32, capacity_factor=0.1),
    )
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(5))
    toks = jax.random.randint(jax.random.PRNGKey(6), (4, 64), 0, 32)
    _, aux = tfm.forward(params, cfg, toks)
    assert int(aux["moe_drops"]) > 0  # tiny capacity factor must drop


def test_lm_loss_decreases():
    from repro.data.batches import lm_batch
    from repro.train import AdamWConfig, make_train_step
    from repro.train.step import init_train_state

    cfg = tfm.TransformerConfig(
        name="t2", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=128, dtype="float32",
    )
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    state = init_train_state(params)
    step = jax.jit(
        make_train_step(lambda p, b: tfm.loss_fn(p, cfg, b), AdamWConfig(lr=3e-3))
    )
    batch = jax.tree.map(jnp.asarray, lm_batch(8, 32, 128, seed=0))
    losses = []
    for _ in range(25):  # same batch: loss must drop
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::6]


# ---------------------------------------------------------------------------
# GIN
# ---------------------------------------------------------------------------


def test_gin_matches_dense_adjacency_oracle():
    cfg = gnn.GINConfig(name="g", n_layers=2, d_in=6, d_hidden=8, n_classes=3)
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    N, E = 20, 60
    feats = jax.random.normal(jax.random.PRNGKey(1), (N, 6))
    edges = jax.random.randint(jax.random.PRNGKey(2), (2, E), 0, N)
    batch = {"feats": feats, "edges": edges,
             "edge_w": jnp.ones((E,)), "labels": jnp.zeros((N,), jnp.int32)}
    logits = np.array(gnn.forward(params, cfg, batch))

    # numpy oracle with dense adjacency
    A = np.zeros((N, N), np.float32)
    for s, d in np.array(edges).T:
        A[d, s] += 1.0
    h = np.array(feats)
    P = {k: np.array(v) for k, v in params.items()}
    relu = lambda x: np.maximum(x, 0)
    z = (1 + P["eps"][0]) * h + A @ h
    h = relu(relu(z @ P["in_w1"] + P["in_b1"]) @ P["in_w2"] + P["in_b2"])
    z = (1 + P["eps"][1]) * h + A @ h
    h = relu(relu(z @ P["w1"][0] + P["b1"][0]) @ P["w2"][0] + P["b2"][0])
    want = h @ P["out_w"] + P["out_b"]
    np.testing.assert_allclose(logits, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_gin_edge_order_invariance(seed):
    """Permuting the edge list must not change the output (sum agg)."""
    cfg = gnn.GINConfig(name="g", n_layers=2, d_in=4, d_hidden=8, n_classes=2)
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    N, E = 15, 40
    key = jax.random.PRNGKey(seed)
    feats = jax.random.normal(key, (N, 4))
    edges = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, E), 0, N)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 2), E)
    b1 = {"feats": feats, "edges": edges, "edge_w": jnp.ones((E,)),
          "labels": jnp.zeros((N,), jnp.int32)}
    b2 = dict(b1, edges=edges[:, perm])
    np.testing.assert_allclose(
        np.array(gnn.forward(params, cfg, b1)),
        np.array(gnn.forward(params, cfg, b2)),
        rtol=1e-5, atol=1e-5,
    )


def test_gin_padded_edges_are_noops():
    cfg = gnn.GINConfig(name="g", n_layers=2, d_in=4, d_hidden=8, n_classes=2)
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    N, E = 15, 30
    feats = jax.random.normal(jax.random.PRNGKey(1), (N, 4))
    edges = jax.random.randint(jax.random.PRNGKey(2), (2, E), 0, N)
    b1 = {"feats": feats, "edges": edges, "edge_w": jnp.ones((E,)),
          "labels": jnp.zeros((N,), jnp.int32)}
    pad = jnp.zeros((2, 10), jnp.int32)
    b2 = {
        "feats": feats,
        "edges": jnp.concatenate([edges, pad], 1),
        "edge_w": jnp.concatenate([jnp.ones((E,)), jnp.zeros((10,))]),
        "labels": b1["labels"],
    }
    np.testing.assert_allclose(
        np.array(gnn.forward(params, cfg, b1)),
        np.array(gnn.forward(params, cfg, b2)),
        rtol=1e-5, atol=1e-5,
    )


# ---------------------------------------------------------------------------
# recsys
# ---------------------------------------------------------------------------


def test_embedding_bag_oracle():
    table = jax.random.normal(jax.random.PRNGKey(0), (50, 8))
    ids = jax.random.randint(jax.random.PRNGKey(1), (6, 4), 0, 50)
    valid = jax.random.bernoulli(jax.random.PRNGKey(2), 0.7, (6, 4))
    out = np.array(recsys.embedding_bag(table, ids, valid=valid))
    T, I, V = np.array(table), np.array(ids), np.array(valid)
    want = np.stack([(T[I[b]] * V[b][:, None]).sum(0) for b in range(6)])
    np.testing.assert_allclose(out, want, rtol=1e-5)
    out_mean = np.array(recsys.embedding_bag(table, ids, mode="mean", valid=valid))
    denom = np.maximum(1, V.sum(1))[:, None]
    np.testing.assert_allclose(out_mean, want / denom, rtol=1e-5)


def test_din_padding_history_is_masked():
    cfg = recsys.DINConfig(name="d", vocab=100, seq_len=6, attn_mlp=(8,),
                           mlp=(8,))
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    hist1 = jnp.asarray([[3, 4, 5, 0, 0, 0]])
    hist2 = jnp.asarray([[3, 4, 5, 7, 9, 11]])  # extra (non-pad) items
    t = jnp.asarray([42])
    s1 = float(recsys.din_forward(params, cfg, {"hist": hist1, "target": t})[0])
    s1b = float(
        recsys.din_forward(
            params, cfg, {"hist": jnp.asarray([[3, 4, 5, 0, 0, 0]]), "target": t}
        )[0]
    )
    s2 = float(recsys.din_forward(params, cfg, {"hist": hist2, "target": t})[0])
    assert s1 == s1b
    assert abs(s1 - s2) > 1e-7  # real items do change the score


def test_twotower_training_separates_pairs():
    from repro.data.batches import twotower_batch
    from repro.train import AdamWConfig, make_train_step
    from repro.train.step import init_train_state

    cfg = recsys.TwoTowerConfig(name="tt", vocab_per_field=200, field_dim=8,
                                tower_mlp=(32, 16), embed_dim=16)
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    state = init_train_state(params)
    step = jax.jit(
        make_train_step(
            lambda p, b: recsys.twotower_loss(p, cfg, b), AdamWConfig(lr=3e-3)
        )
    )
    accs = []
    for i in range(30):
        b = jax.tree.map(jnp.asarray, twotower_batch(32, 4, 4, 200, seed=i % 4))
        params, state, m = step(params, state, b)
        accs.append(float(m["acc"]))
    assert np.mean(accs[-5:]) > np.mean(accs[:5]) + 0.2, accs[::6]
