"""Observability: span-tree fan-in integrity under coalesced batches,
deterministic sampling, the hard bit-identity invariant (traced ==
untraced ids AND distances at shards 1-3), Chrome/JSONL export
round-trips, the unified metrics registry, and bounded-memory
LatencyStats (exact by default, seeded reservoir when bounded)."""

import gc
import importlib.util
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.tree import build_tree
from repro.data import synth
from repro.distributed.meshutil import local_mesh
from repro.index import Index
from repro.obs import (
    NULL_SPAN,
    NULL_TRACER,
    MetricsRegistry,
    Span,
    Tracer,
    chrome_trace_events,
    summary,
    tracing,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.export import PID_ENGINE, PID_REQUESTS, PID_SHARD_BASE
from repro.serving import (
    MicroBatcher,
    SearchSession,
    ShardedSearchSession,
    TraceLoadGenerator,
)
from repro.serving.metrics import HIST_BOUNDS_MS, LatencyStats, ServingMetrics

DIM = 16
N = 2000


@pytest.fixture(scope="module")
def corpus():
    vecs_np, _ = synth.sample_descriptors(N, DIM, seed=0, n_centers=40)
    tree = build_tree(jnp.asarray(vecs_np), (8, 4), key=jax.random.PRNGKey(1))
    return vecs_np, tree, local_mesh()


@pytest.fixture(scope="module")
def grown(corpus):
    """Three-segment in-memory index, so shards 1-3 are all non-empty."""
    vecs_np, tree, mesh = corpus
    idx = Index.create(tree, None, mesh=mesh)
    for lo, hi in ((0, 500), (500, 1500), (1500, N)):
        idx.append(vecs_np[lo:hi])
    idx.commit()
    return idx


def _replay(corpus, idx, *, shards, tracer, n_requests=40, rate=2000.0,
            cache_leaves=0):
    """One seeded zipf replay; returns (completions, session). The trace
    is deterministic given the seed, so two replays see identical
    requests — only the tracer differs. Bit-identity comparisons keep the
    hot-leaf cache OFF: the virtual clock advances by measured wall
    compute, so cache admission timing can differ between replays, and a
    cache-served answer is a CPU recompute under a rounding contract
    (tests/test_serving.py), not the engine's bits. Engine results are
    batch-composition invariant, so engine-only replays are deterministic
    by construction."""
    vecs_np, tree, mesh = corpus
    if shards is None:
        s = SearchSession(idx, k=5, layout="point_major", probes=2,
                          buckets=(32, 96), cache_leaves=cache_leaves,
                          cache_admit_after=1)
    else:
        s = ShardedSearchSession(idx, shards=shards, k=5,
                                 layout="point_major", probes=2,
                                 buckets=(32, 96), cache_leaves=cache_leaves,
                                 cache_admit_after=1)
    s.warmup()
    gen = TraceLoadGenerator(vecs_np, 20, seed=3)
    reqs = gen.from_trace(n_requests, N // 20, skew="zipf", rate=rate)
    with tracing(tracer):
        done = MicroBatcher(s, max_wait_ms=4.0, max_queue=1024).run(reqs)
    return done, s


@pytest.fixture(scope="module")
def traced2(corpus, grown):
    """One traced 2-shard replay shared by the export/fan-in tests (cache
    enabled here — no cross-run comparison, just span coverage)."""
    tracer = Tracer(sample=1.0, seed=0)
    done, _ = _replay(corpus, grown, shards=2, tracer=tracer,
                      cache_leaves=32)
    return tracer, done


# ---------------------------------------------------------------------------
# Tracer unit behavior
# ---------------------------------------------------------------------------


def test_tracer_records_span_tree():
    tr = Tracer()
    with tr.span("outer", kind_of="root") as outer:
        with tr.span("inner") as inner:  # auto-parents under outer
            inner.set(rows=3)
        ex = tr.add_span("explicit", 1.0, 2.0, trace_id=7, parent=outer,
                         shard=1)
        ev = tr.event("tick", t=1.5, trace_id=7)
    assert inner.parent_id == outer.span_id
    assert ex.parent_id == outer.span_id and ex.trace_id == 7
    assert ex.dur_ms == pytest.approx(1000.0)
    assert ev.kind == "event" and ev.dur_ms == 0.0
    assert outer.t1 is not None and outer.t1 >= outer.t0
    assert len(tr) == 4 and tr.n_events() == 1
    d = tr.describe()
    assert d == {"enabled": True, "sample": 1.0, "spans": 3, "events": 1,
                 "dropped": 0}


def test_tracer_max_spans_cap_counts_drops():
    tr = Tracer(max_spans=2)
    a = tr.add_span("a", 0.0, 1.0)
    b = tr.add_span("b", 0.0, 1.0)
    c = tr.add_span("c", 0.0, 1.0)  # over the cap: dropped, not recorded
    assert isinstance(a, Span) and isinstance(b, Span)
    assert c is NULL_SPAN
    assert len(tr) == 2 and tr.dropped == 1
    with tr.span("d") as d:  # context-manager path drops too
        assert d is NULL_SPAN
    assert tr.dropped == 2


def test_tracer_validates_sample_rate():
    with pytest.raises(ValueError, match="must be in"):
        Tracer(sample=1.5)


def test_timebase_rebases_wall_spans():
    tr = Tracer()
    with tr.timebase(5.0):
        with tr.span("work") as s:
            pass
    assert 5.0 <= s.t0 < 5.5  # lands at virtual time, not wall time
    assert s.t1 >= s.t0
    assert tr.now() < 5.0  # restored after the block


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    assert len(NULL_TRACER) == 0
    assert NULL_TRACER.sampled(1) is False
    assert NULL_TRACER.add_span("x", 0, 1) is NULL_SPAN
    assert NULL_TRACER.event("x") is NULL_SPAN
    with NULL_TRACER.span("x") as s:
        assert s.set(rows=1) is s
    assert NULL_TRACER.describe() == {"enabled": False, "sample": 0.0,
                                      "spans": 0, "events": 0, "dropped": 0}


def test_sampling_is_deterministic_given_seed():
    rids = range(400)
    a = Tracer(sample=0.35, seed=7)
    b = Tracer(sample=0.35, seed=7)
    da = [a.sampled(r) for r in rids]
    db = [b.sampled(r) for r in rids]
    assert da == db  # same seed -> same traced subset, always
    assert a.dropped == b.dropped == da.count(False)
    assert 0.15 < sum(da) / len(da) < 0.55  # roughly the asked-for rate
    c = Tracer(sample=0.35, seed=8)
    assert [c.sampled(r) for r in rids] != da  # seed changes the subset
    full = Tracer(sample=1.0)
    assert all(full.sampled(r) for r in rids) and full.dropped == 0


# ---------------------------------------------------------------------------
# span-tree fan-in under coalesced batches
# ---------------------------------------------------------------------------


def test_fan_in_integrity_under_coalesced_batches(traced2):
    tracer, done = traced2
    spans = tracer.spans
    dispatches = [s for s in spans if s.name == "engine.dispatch"]
    requests = [s for s in spans if s.name == "request"]
    engine_reqs = [s for s in requests if s.attrs.get("source") == "engine"]
    assert dispatches and engine_reqs
    # rate=2000 forces coalescing: at least one dispatch serves >1 request
    assert max(len(d.attrs["rids"]) for d in dispatches) > 1
    by_dispatch = {}
    for r in engine_reqs:
        by_dispatch.setdefault(r.attrs["dispatch_id"], []).append(r)
    # every engine-served request fans into exactly one dispatch span,
    # and each dispatch's fan-in is exactly its recorded rid set
    assert sum(len(v) for v in by_dispatch.values()) == len(engine_reqs)
    for d in dispatches:
        fan_in = by_dispatch.get(d.span_id, [])
        assert {r.trace_id for r in fan_in} == set(d.attrs["rids"])
    # each request span owns exactly one queue.wait and one compute child
    for r in requests:
        kids = [s for s in spans if s.parent_id == r.span_id]
        names = sorted(k.name for k in kids if k.name != "cache.lookup")
        assert names == ["compute", "queue.wait"]
        for k in kids:
            assert k.trace_id == r.trace_id
            assert r.t0 <= k.t0 and k.t1 <= r.t1 + 1e-9
    # every completion produced a request span (sample=1.0: none dropped)
    assert {s.trace_id for s in requests} == {c.rid for c in done}
    # scatter legs cover both shards; the merge closes each dispatch
    shard_lanes = {s.attrs["shard"] for s in spans if s.name == "shard.scan"}
    assert shard_lanes == {0, 1}
    assert any(s.name == "gather.merge" for s in spans)


# ---------------------------------------------------------------------------
# the hard invariant: tracing never perturbs results (shards 1-3)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shards", [1, 2, 3])
def test_bit_identity_traced_vs_untraced(corpus, grown, shards):
    base, _ = _replay(corpus, grown, shards=shards, tracer=None)
    tracer = Tracer(sample=1.0, seed=0)
    traced, _ = _replay(corpus, grown, shards=shards, tracer=tracer)
    assert len(tracer) > 0  # the traced leg really recorded
    ref = {c.rid: c for c in base}
    assert set(ref) == {c.rid for c in traced}
    for c in traced:
        r = ref[c.rid]
        np.testing.assert_array_equal(np.asarray(c.ids), np.asarray(r.ids))
        np.testing.assert_array_equal(np.asarray(c.dists),
                                      np.asarray(r.dists))


# ---------------------------------------------------------------------------
# exporters: Chrome trace_event + JSONL round-trips
# ---------------------------------------------------------------------------


def test_chrome_export_roundtrip(traced2, tmp_path):
    tracer, _ = traced2
    path = write_chrome_trace(tracer, str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)  # valid JSON or this raises
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["enabled"] is True
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    body = [e for e in events if e["ph"] != "M"]
    assert len(body) == len(tracer.spans)
    # monotone timestamps (the sort contract Perfetto relies on)
    ts = [e["ts"] for e in body]
    assert ts == sorted(ts)
    # pid/tid placement: one process lane per shard, requests keyed by rid
    names = {e["pid"]: e["args"]["name"] for e in meta}
    assert names[PID_SHARD_BASE] == "shard 0"
    assert names[PID_SHARD_BASE + 1] == "shard 1"
    assert names[PID_REQUESTS] == "requests" and names[PID_ENGINE] == "engine"
    for e in body:
        assert e["ph"] in ("X", "i")
        if e["name"] == "shard.scan":
            assert e["pid"] == PID_SHARD_BASE + e["args"]["shard"]
        elif e["name"] in ("engine.dispatch", "engine.execute",
                           "gather.merge"):
            assert e["pid"] == PID_ENGINE
        elif e["name"] == "request":
            assert e["pid"] == PID_REQUESTS
            assert e["tid"] == e["args"]["trace_id"]
        if e["ph"] == "X":
            assert e["dur"] >= 0.0


def test_jsonl_export_roundtrip(traced2, tmp_path):
    tracer, _ = traced2
    path = write_jsonl(tracer, str(tmp_path / "trace.jsonl"))
    with open(path) as f:
        lines = [json.loads(ln) for ln in f]
    assert lines[0] == {"header": tracer.describe()}
    assert len(lines) - 1 == len(tracer.spans)
    for rec, span in zip(lines[1:], tracer.spans):
        assert rec["name"] == span.name
        assert rec["dur_ms"] == pytest.approx(span.dur_ms)


def test_summary_and_tracereport_read_both_formats(traced2, tmp_path):
    tracer, _ = traced2
    text = summary(tracer, top=3)
    assert "slowest requests" in text and "shard.scan" in text
    # scripts/tracereport.py is stdlib-only; load it straight off disk
    script = os.path.join(os.path.dirname(__file__), os.pardir, "scripts",
                          "tracereport.py")
    spec = importlib.util.spec_from_file_location("tracereport", script)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    chrome = write_chrome_trace(tracer, str(tmp_path / "t.json"))
    jsonl = write_jsonl(tracer, str(tmp_path / "t.jsonl"))
    for path in (chrome, jsonl):
        report = mod.report(mod._load_spans(path), top=3)
        assert "slowest requests" in report
        assert "shard 0" in report and "shard 1" in report


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------


def test_registry_instruments_get_or_create_and_labels():
    reg = MetricsRegistry()
    c = reg.counter("serving.requests")
    c.inc()
    assert reg.counter("serving.requests") is c  # get-or-create identity
    reg.counter("serving.class.completed", cls="interactive").inc(2)
    reg.counter("serving.class.completed", cls="batch").inc()
    reg.gauge("index.version").set(3)
    h = reg.histogram("latency.ms")
    for v in (0.5, 3.0, 3.0, 1e6):
        h.observe(v)
    snap = reg.snapshot()["metrics"]
    assert snap["serving.requests"] == 1
    assert snap["serving.class.completed{cls=interactive}"] == 2
    assert snap["serving.class.completed{cls=batch}"] == 1
    assert snap["index.version"] == 3
    assert snap["latency.ms"]["count"] == 4
    assert snap["latency.ms"]["counts"][0] == 1  # <= 1ms bucket
    assert snap["latency.ms"]["counts"][-1] == 1  # overflow bucket
    assert snap["latency.ms"]["max"] == 1e6
    assert len(reg) == 5
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("serving.requests")
    # float counters export as float, integral ones as int
    reg.counter("engine.ms").inc(1.5)
    snap = reg.snapshot()["metrics"]
    assert snap["engine.ms"] == 1.5 and isinstance(snap["engine.ms"], float)
    assert isinstance(snap["serving.requests"], int)


def test_registry_sources_are_weak(tmp_path):
    class Box:
        def series(self):
            return {"box.value": 42}

    reg = MetricsRegistry()
    box = Box()
    reg.register_source("box", box, Box.series)
    assert reg.snapshot()["sources"] == {"box": {"box.value": 42}}
    path = reg.dump(str(tmp_path / "metrics.json"))
    with open(path) as f:
        assert json.load(f)["sources"]["box"]["box.value"] == 42
    del box
    gc.collect()
    assert reg.snapshot()["sources"] == {}  # dead owner pruned, not stale
    reg.register_source("box2", self_ := Box(), Box.series)
    reg.unregister_source("box2")
    assert reg.snapshot()["sources"] == {}
    assert self_ is not None


def test_serving_and_cache_register_in_process_registry():
    from repro.serving.cache import HotLeafCache

    reg = obs.get_registry()  # fresh per test (conftest isolation)
    m = ServingMetrics()
    m.requests = 5
    cache = HotLeafCache(8, admit_after=1)
    sources = reg.snapshot()["sources"]
    mine = [s for n, s in sources.items() if n.startswith("serving_metrics@")]
    assert any(s["serving.requests"] == 5 for s in mine)
    cs = [s for n, s in sources.items() if n.startswith("hot_leaf_cache@")]
    assert any(s["cache.hits"] == 0 for s in cs)
    del m, cache
    gc.collect()
    sources = reg.snapshot()["sources"]
    assert not any(n.startswith("serving_metrics@") for n in sources)
    assert not any(n.startswith("hot_leaf_cache@") for n in sources)


# ---------------------------------------------------------------------------
# LatencyStats: exact default, bounded reservoir mode
# ---------------------------------------------------------------------------


def test_latency_stats_exact_default_unchanged():
    ls = LatencyStats()
    for v in range(1, 101):
        ls.add(float(v))
    assert len(ls) == 100
    assert ls.percentile(50) == pytest.approx(50.5)
    s = ls.summary()
    assert s["count"] == 100
    assert s["mean_ms"] == pytest.approx(50.5)
    assert s["max_ms"] == 100.0
    assert LatencyStats().summary() == {"count": 0}
    h = ls.histogram()
    assert h["bounds_ms"] == list(HIST_BOUNDS_MS)
    assert sum(h["counts"]) == 100
    assert h["counts"][0] == 1  # only 1.0 <= 1ms


def test_latency_stats_reservoir_bounds_memory_exactly():
    with pytest.raises(ValueError, match="must be >= 1"):
        LatencyStats(0)
    exact = LatencyStats()
    bounded = LatencyStats(32, seed=0)
    vals = np.random.default_rng(5).uniform(0.1, 400.0, size=1000)
    for v in vals:
        exact.add(float(v))
        bounded.add(float(v))
    # count / mean / max / histogram stay exact; retention is bounded
    assert len(bounded) == 1000 and len(bounded._ms) == 32
    assert bounded.summary()["count"] == 1000
    assert bounded.summary()["mean_ms"] == pytest.approx(
        exact.summary()["mean_ms"]
    )
    assert bounded.summary()["max_ms"] == exact.summary()["max_ms"]
    assert bounded.histogram() == exact.histogram()
    # percentiles are estimates from retained samples, inside the range
    assert vals.min() <= bounded.percentile(50) <= vals.max()
    # deterministic: same seed + same sequence -> same reservoir
    again = LatencyStats(32, seed=0)
    for v in vals:
        again.add(float(v))
    assert again._ms == bounded._ms


def test_serving_metrics_bounded_mode_and_to_dict_shape():
    m = ServingMetrics(max_samples=16)
    for i in range(200):
        m.observe_latency("interactive" if i % 3 else "batch",
                          wait_ms=float(i % 7), compute_ms=1.0,
                          deadline_ms=50.0)
        m.observe_queue_depth(i % 11)
    m.requests = 200
    d = m.to_dict()
    assert d["latency"]["count"] == 200  # exact despite the bound
    assert len(m.queue_depth) == 16
    assert m.queue_summary()["count"] == 200
    # the historical to_dict surface is unchanged (byte-compat contract)
    assert list(d) == list(ServingMetrics().to_dict())
    assert list(d["per_class"]["batch"]) == [
        "completed", "shed", "rejected", "attained", "slo_attainment",
        "deadline_ms", "latency", "wait", "compute",
    ]
    # the additive registry view carries the same numbers, labeled
    series = m.registry_series()
    assert series["serving.requests"] == 200
    assert series["serving.class.completed{class=batch}"] == \
        d["per_class"]["batch"]["completed"]
    assert sum(series["serving.latency.hist"]["counts"]) == 200
    m.observe_drop("batch", "shed")
    assert m.registry_series()["serving.class.shed{class=batch}"] == 1
    with pytest.raises(ValueError, match="unknown drop kind"):
        m.observe_drop("batch", "nope")
