"""Logical-axis partitioning rules + divisibility fallback (AbstractMesh —
no need for 256 real devices)."""

import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.meshutil import abstract_mesh
from repro.distributed.partitioning import DEFAULT_RULES, partition_spec

MESH_1POD = abstract_mesh((16, 16), ("data", "model"))
MESH_2POD = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_batch_shards_over_pod_and_data():
    spec = partition_spec((256, 4096), ("batch", None), MESH_2POD, DEFAULT_RULES)
    assert spec == P(("pod", "data"), None)


def test_divisibility_fallback_heads():
    # llama3.2: 24 heads don't divide model=16 -> replicate that dim
    spec = partition_spec((28, 24, 128), ("layers", "heads", "head_dim"),
                          MESH_1POD, DEFAULT_RULES)
    assert spec == P(None, None, None)
    # but the fused qkv projection (3072) shards
    spec = partition_spec((28, 3072, 3072), ("layers", "embed", "qkv"),
                          MESH_1POD, DEFAULT_RULES)
    assert spec == P(None, None, "model")


def test_axis_used_once_per_array():
    # both dims want 'model'; first one wins, second replicates
    spec = partition_spec((64, 1408), ("experts", "ffn"), MESH_1POD,
                          DEFAULT_RULES)
    assert spec == P("model", None)


def test_kv_seq_takes_free_axes():
    # decode_32k: batch takes (pod,data); kv_seq gets model
    shape = (32, 128, 32768, 8, 128)
    axes = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    spec = partition_spec(shape, axes, MESH_2POD, DEFAULT_RULES)
    assert spec == P(None, ("pod", "data"), "model", None, None)
    # long_500k: batch=1 replicates; kv_seq gets all three axes
    shape = (32, 1, 524288, 8, 128)
    spec = partition_spec(shape, axes, MESH_2POD, DEFAULT_RULES)
    assert spec == P(None, None, ("pod", "data", "model"), None, None)


def test_non_divisible_batch_replicates():
    spec = partition_spec((1, 128), ("batch", None), MESH_2POD, DEFAULT_RULES)
    assert spec == P(None, None)


def test_rank_mismatch_raises():
    with pytest.raises(ValueError, match="rank"):
        partition_spec((4, 4), ("batch",), MESH_1POD, DEFAULT_RULES)


def test_rules_extension():
    rules = DEFAULT_RULES.extend(qkv=None)
    spec = partition_spec((32, 3072), ("embed", "qkv"), MESH_1POD, rules)
    assert spec == P(None, None)


def test_vocab_shards_all_lm_archs():
    for v in (128256, 262144, 92544, 163840, 32064):
        spec = partition_spec((v, 2048), ("vocab", "embed"), MESH_1POD,
                              DEFAULT_RULES)
        assert spec == P("model", None), v
