"""Serving layer: bucket snapping never recompiles inside the warmed set,
micro-batched results are bit-identical to direct batch_search, traces are
deterministic, the hot-leaf cache is exact, and the index persists."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (
    bucket_ladder,
    observations,
    plan as make_plan,
    record_observation,
    reset_observations,
    snap_to_bucket,
)
from repro.core.index_build import build_index
from repro.core.lookup import build_lookup, build_lookup_bucketed
from repro.core.search import batch_search
from repro.core.tree import build_tree
from repro.data import synth
from repro.distributed.meshutil import local_mesh
from repro.serving import (
    HotLeafCache,
    MicroBatcher,
    SearchSession,
    TraceLoadGenerator,
    persist,
)

DIM = 24
DPI = 8  # descriptors per image in the serving tests


@pytest.fixture(scope="module")
def corpus():
    vecs_np, _ = synth.sample_descriptors(3000, DIM, seed=0, n_centers=50)
    vecs = jnp.asarray(vecs_np)
    tree = build_tree(vecs, (8, 4), key=jax.random.PRNGKey(1))
    mesh = local_mesh()
    index = build_index(vecs, tree, mesh, wire_dtype=jnp.float32)
    q_np = np.array(vecs[:80]) + np.random.default_rng(2).standard_normal(
        (80, DIM)
    ).astype(np.float32)
    return vecs_np, tree, mesh, index, q_np


@pytest.fixture(scope="module")
def session(corpus):
    vecs_np, tree, mesh, index, q_np = corpus
    s = SearchSession(index, tree, mesh, k=5, layout="point_major",
                      probes=2, buckets=(32, 96))
    s.warmup()
    return s


# ---------------------------------------------------------------------------
# bucket ladder / snapping / plan observations
# ---------------------------------------------------------------------------


def test_bucket_ladder_divisors_and_snap():
    b = bucket_ladder(4096, n_buckets=4, min_queries=32)
    assert b[-1] == 4096 and len(b) == 4
    assert all(4096 % x == 0 for x in b)  # rungs divide the top rung
    assert b == tuple(sorted(b))
    assert snap_to_bucket(1, b) == b[0]
    assert snap_to_bucket(b[0], b) == b[0]
    assert snap_to_bucket(b[0] + 1, b) == b[1]
    assert snap_to_bucket(4096, b) == 4096
    assert snap_to_bucket(9999, b) == 4096  # caller splits oversize batches
    with pytest.raises(ValueError):
        snap_to_bucket(0, b)
    # degenerate ladders still work (primes collapse to {1, n})
    small = bucket_ladder(7, n_buckets=3, min_queries=1)
    assert small[-1] == 7 and all(7 % x == 0 for x in small)


def test_plan_observations_registry():
    reset_observations()
    p = make_plan(rows=8192, n_leaves=64, n_queries=128, n_shards=1, k=5,
                  layout="point_major")
    p.observe(12.5)
    p.observe(7.5)
    record_observation(p, 10.0)
    obs = observations()
    assert len(obs) == 1
    (key, o), = obs.items()
    assert key.startswith("point_major/k=5/")
    assert o["count"] == 3
    assert o["min_ms"] == 7.5 and o["max_ms"] == 12.5
    assert o["mean_ms"] == pytest.approx(10.0)
    assert o["last_ms"] == 10.0
    reset_observations()
    assert observations() == {}


def test_plan_observed_preference_flip():
    """The cost-model consult loop, on by default: measured ms/image under
    both candidate signatures overrides the heuristic's layout pick; with
    fewer than two measured candidates the heuristic still decides."""
    reset_observations()
    shapes = dict(rows=65_536, n_leaves=64, n_queries=256, n_shards=1, k=10)
    modelled = make_plan(layout="auto", model="heuristic", **shapes)
    pm = make_plan(layout="point_major", **shapes)
    qr = make_plan(layout="query_routed", **shapes)
    winner, loser = (pm, qr) if modelled.layout == pm.layout else (qr, pm)
    # measurements contradict the model: the modelled winner is slow
    record_observation(winner, 100.0)
    assert make_plan(
        layout="auto", **shapes
    ).layout == modelled.layout  # one measurement: heuristic still decides
    record_observation(loser, 1.0)
    flipped = make_plan(layout="auto", **shapes)
    assert flipped.layout == loser.layout  # both measured: data wins
    # the explicit spelling agrees with the default consult loop
    assert make_plan(
        layout="auto", model="observed", **shapes
    ).layout == loser.layout
    # model="heuristic" pins the shape rules regardless of observations
    assert make_plan(
        layout="auto", model="heuristic", **shapes
    ).layout == modelled.layout
    reset_observations()
    assert make_plan(layout="auto", **shapes).layout == modelled.layout
    reset_observations()


# ---------------------------------------------------------------------------
# bucketed lookup build
# ---------------------------------------------------------------------------


def test_bucketed_lookup_matches_build_lookup(corpus):
    vecs_np, tree, mesh, index, q_np = corpus
    q = jnp.asarray(q_np[:32])
    for probes in (1, 3):
        lk = build_lookup(tree, q, probes=probes)
        blk, leaves = jax.jit(
            build_lookup_bucketed, static_argnames=("probes", "q_total")
        )(tree, q, 32, probes=probes, q_total=32 * probes)
        assert leaves.shape == (32, probes)
        for a, b in zip(
            (lk.vecs, lk.qids, lk.leaves, lk.offsets),
            (blk.vecs, blk.qids, blk.leaves, blk.offsets),
        ):
            np.testing.assert_array_equal(np.array(a), np.array(b))


def test_bucketed_lookup_masks_padding(corpus):
    """Rows past n_valid never reach a real leaf; real rows keep their
    exact build_lookup ordering and CSR spans."""
    vecs_np, tree, mesh, index, q_np = corpus
    n_valid, bucket, probes = 20, 32, 2
    buf = np.zeros((bucket, DIM), np.float32)
    buf[:n_valid] = q_np[:n_valid]
    blk, _ = build_lookup_bucketed(
        tree, jnp.asarray(buf), n_valid, probes=probes,
        q_total=bucket * probes + probes,
    )
    lv = np.array(blk.leaves)
    qids = np.array(blk.qids)
    real = lv >= 0
    assert real.sum() == n_valid * probes
    # every real row's flat slot belongs to a valid query
    assert (qids[real] < n_valid * probes).all()
    # CSR offsets span exactly the real rows
    off = np.array(blk.offsets)
    assert off[-1] - off[0] == n_valid * probes
    # direct build over just the valid queries orders rows identically
    lk = build_lookup(tree, jnp.asarray(q_np[:n_valid]), probes=probes)
    np.testing.assert_array_equal(np.array(lk.qids), qids[real])
    np.testing.assert_array_equal(np.array(lk.leaves), lv[real])


# ---------------------------------------------------------------------------
# session: no recompiles in the warmed set + bit-identical results
# ---------------------------------------------------------------------------


def test_no_recompile_within_warmed_buckets(session, corpus):
    vecs_np, tree, mesh, index, q_np = corpus
    warmed = session.recompiles()
    assert warmed == len(session.buckets)  # one program per rung
    for n in (1, 7, 31, 32, 33, 64, 96):
        session.search(q_np[:n])
    # oversize batches split across dispatches, still no new programs
    big = np.concatenate([q_np, q_np])  # 160 rows > max bucket 96
    session.search(big)
    assert session.recompiles() == warmed
    assert session.steady_state_recompiles() == 0


@pytest.mark.parametrize("layout", ["point_major", "query_routed"])
def test_microbatched_bit_identical_to_direct(corpus, layout):
    """The acceptance invariant: session results == direct batch_search,
    exactly, on both layouts — padding/masking never perturbs a result."""
    vecs_np, tree, mesh, index, q_np = corpus
    s = SearchSession(index, tree, mesh, k=5, layout=layout, probes=2,
                      buckets=(96,))
    s.warmup()
    for n in (96, 50, 17):  # exact-fill and padded buckets
        ids, dists = s.search(q_np[:n])
        p = s._runtimes[96].plan
        kw = (
            dict(block_rows=p.block_rows, q_cap=p.q_cap)
            if layout == "point_major"
            else dict(q_tile=p.q_tile, p_cap=p.p_cap)
        )
        direct = batch_search(index, tree, jnp.asarray(q_np[:n]), k=5,
                              mesh=mesh, layout=layout, probes=2, **kw)
        np.testing.assert_array_equal(ids, np.array(direct.ids))
        np.testing.assert_array_equal(dists, np.array(direct.dists))


def test_serve_many_splits_per_request(session, corpus):
    vecs_np, tree, mesh, index, q_np = corpus
    parts = [q_np[:10], q_np[10:14], q_np[14:40]]
    outs = session.serve_many(parts)
    whole_i, whole_d = session.search(q_np[:40])
    off = 0
    for (ids, dists), part in zip(outs, parts):
        assert ids.shape == (len(part), session.k)
        np.testing.assert_array_equal(ids, whole_i[off: off + len(part)])
        off += len(part)


# ---------------------------------------------------------------------------
# traces: determinism + skew
# ---------------------------------------------------------------------------


def test_trace_deterministic_and_skewed():
    a_img, a_t = synth.sample_trace(500, 100, skew="zipf", rate=50.0, seed=9)
    b_img, b_t = synth.sample_trace(500, 100, skew="zipf", rate=50.0, seed=9)
    np.testing.assert_array_equal(a_img, b_img)
    np.testing.assert_array_equal(a_t, b_t)
    c_img, _ = synth.sample_trace(500, 100, skew="zipf", rate=50.0, seed=10)
    assert not np.array_equal(a_img, c_img)
    assert (np.diff(a_t) >= 0).all()  # arrivals are a point process
    u_img, u_t = synth.sample_trace(500, 100, skew="uniform", seed=9)
    assert (u_t == 0).all()  # no rate -> offline batch trace
    # zipf concentrates mass: top-10 images absorb far more than uniform's
    top = lambda ids: np.sort(np.bincount(ids, minlength=100))[-10:].sum()
    assert top(a_img) > 2 * top(u_img)
    with pytest.raises(ValueError):
        synth.sample_trace(10, 100, skew="bogus")


def test_trace_generator_repeats_are_identical(corpus):
    vecs_np, tree, mesh, index, q_np = corpus
    gen = TraceLoadGenerator(vecs_np, DPI, seed=5)
    # same image -> the same photo -> identical query descriptors
    np.testing.assert_array_equal(gen.query_image(7), gen.query_image(7))
    reqs = gen.requests(np.array([3, 7, 3]), np.array([0.0, 0.1, 0.2]))
    assert [r.rows for r in reqs] == [DPI] * 3
    np.testing.assert_array_equal(reqs[0].queries, reqs[2].queries)
    assert not np.array_equal(reqs[0].queries, reqs[1].queries)


# ---------------------------------------------------------------------------
# micro-batcher: coalescing, deadline, backpressure
# ---------------------------------------------------------------------------


def test_batcher_coalesces_and_respects_backpressure(corpus):
    vecs_np, tree, mesh, index, q_np = corpus
    s = SearchSession(index, tree, mesh, k=3, layout="point_major",
                      buckets=(64,))
    s.warmup()
    gen = TraceLoadGenerator(vecs_np, DPI, seed=5)
    # burst of 12 requests at t=0, 8 requests/bucket (64 rows / 8 dpi)
    reqs = gen.requests(np.arange(12), np.zeros(12))
    done = MicroBatcher(s, max_wait_ms=5.0, max_queue=4096).run(reqs)
    m = s.metrics
    assert m.requests == 12 and m.rejected == 0
    assert m.engine_batches == 2  # 8 + 4, coalesced
    assert len(m.latency) == 12
    assert all(c.latency_ms >= 0 for c in done)
    # backpressure: a queue cap of 5 rejects the burst's tail
    s2 = SearchSession(index, tree, mesh, k=3, layout="point_major",
                       buckets=(64,))
    s2.warmup()
    done2 = MicroBatcher(s2, max_wait_ms=5.0, max_queue=5).run(
        gen.requests(np.arange(12), np.zeros(12))
    )
    rej = [c for c in done2 if c.source == "rejected"]
    assert len(rej) == 7 and s2.metrics.rejected == 7
    assert all(c.ids is None for c in rej)
    assert s2.metrics.requests == 5


def test_batcher_serves_requests_larger_than_top_bucket(corpus):
    """A single request bigger than the largest bucket is split across
    dispatches by the session instead of crashing the replay."""
    vecs_np, tree, mesh, index, q_np = corpus
    s = SearchSession(index, tree, mesh, k=3, layout="point_major",
                      buckets=(16,))
    s.warmup()
    gen = TraceLoadGenerator(vecs_np, 40, seed=5)  # 40 rows > 16-row bucket
    done = MicroBatcher(s, max_wait_ms=1.0, max_queue=8).run(
        gen.requests(np.arange(2), np.zeros(2))
    )
    assert s.metrics.requests == 2 and s.metrics.rejected == 0
    assert all(c.source == "engine" and c.ids.shape == (40, 3) for c in done)
    assert s.steady_state_recompiles() == 0


def test_batcher_deadline_dispatches_partial_batches(corpus):
    """Sparse arrivals + a tight deadline: every request dispatches alone
    rather than waiting to fill a bucket."""
    vecs_np, tree, mesh, index, q_np = corpus
    s = SearchSession(index, tree, mesh, k=3, layout="point_major",
                      buckets=(64,))
    s.warmup()
    gen = TraceLoadGenerator(vecs_np, DPI, seed=5)
    arrivals = np.arange(4) * 10.0  # 10 s apart >> 1 ms deadline
    done = MicroBatcher(s, max_wait_ms=1.0, max_queue=64).run(
        gen.requests(np.arange(4), arrivals)
    )
    assert s.metrics.engine_batches == 4
    # latency excludes the inter-arrival gaps (virtual clock follows trace)
    assert all(c.latency_ms < 5000 for c in done)


# ---------------------------------------------------------------------------
# hot-leaf cache: hits happen and are exact
# ---------------------------------------------------------------------------


def test_cache_hits_repeated_images_exactly(corpus):
    vecs_np, tree, mesh, index, q_np = corpus
    s = SearchSession(index, tree, mesh, k=3, layout="point_major",
                      probes=2, buckets=(64,), cache_leaves=tree.n_leaves,
                      cache_admit_after=1)
    s.warmup()
    gen = TraceLoadGenerator(vecs_np, DPI, seed=5)
    # images 0..3 arrive cold at t=0, then repeat later (cache-warm)
    image_ids = np.array([0, 1, 2, 3, 0, 1, 2, 3, 0])
    arrivals = np.array([0, 0, 0, 0, 1, 1, 1, 1, 2], np.float64)
    done = MicroBatcher(s, max_wait_ms=5.0, max_queue=64).run(
        gen.requests(image_ids, arrivals)
    )
    m = s.metrics
    assert m.requests == 9
    assert m.cache_images == 5  # every repeat served from cache
    assert s.cache.hits > 0 and s.cache.hit_rate > 0
    # cached answers return the same neighbour ids as the engine did
    by_src = {}
    for c in done:
        by_src.setdefault((c.image_id, c.source), c)
    for img in range(4):
        eng = by_src[(img, "engine")]
        hit = by_src.get((img, "cache"))
        if hit is None:
            continue
        np.testing.assert_array_equal(hit.ids, eng.ids)
        # same candidate set and ids; distances agree to f32 GEMM rounding
        np.testing.assert_allclose(hit.dists, eng.dists, rtol=1e-3, atol=0.5)


def test_cache_stats_safe_before_attach_and_when_disabled():
    """Regression: hit_rate / stats() on an idle, disabled, or
    never-attached cache must be well-formed, never divide by zero, and
    the serve/learn paths must be no-ops rather than crashes."""
    with pytest.raises(ValueError, match="eviction"):
        HotLeafCache(8, eviction="bogus")
    for cache in (HotLeafCache(0), HotLeafCache(8)):  # disabled / unattached
        assert not cache.enabled
        assert cache.hit_rate == 0.0
        st = cache.stats()
        assert st["enabled"] is False and st["hit_rate"] == 0.0
        assert st["resident_bytes"] == 0 and st["cached_leaves"] == 0
        assert st["memo_entries"] == 0 and st["cost_hint_ms"] is None
        # a probe against the idle cache neither serves nor counts a miss
        assert cache.try_serve(np.zeros((2, 4), np.float32), k=3) is None
        cache.record(np.zeros((2, 4), np.float32), np.zeros((2, 1), np.int64))
        assert cache.hits == 0 and cache.misses == 0
        assert cache.stats()["memo_entries"] == 0


def _attached_cache(**kw):
    """A 3-leaf toy index: leaf 0 holds 90 rows, leaves 1/2 hold 5 each."""
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(100, 8)).astype(np.float32)
    leaves = np.array([0] * 90 + [1] * 5 + [2] * 5)
    cache = HotLeafCache(2, admit_after=1, **kw)
    cache.attach_index(vecs, np.arange(100), leaves, n_leaves=3)
    return cache


def _route(cache, leaf, times):
    for i in range(times):
        q = np.full((1, 8), float(leaf * 10 + i), np.float32)
        cache.record(q, np.array([[leaf]]))


def test_cache_cost_eviction_drops_big_lukewarm_slab():
    cache = _attached_cache()  # eviction="cost" is the default
    _route(cache, 1, 3)
    _route(cache, 2, 3)
    assert set(cache._slabs) == {1, 2}
    _route(cache, 0, 1)  # the 90-row slab: huge, touched once, most recent
    # over capacity, the big lukewarm slab saves the fewest ms per
    # resident byte — it goes first even though it is the newest
    assert cache.evictions == 1 and 0 not in cache._slabs
    assert set(cache._slabs) == {1, 2}
    assert cache.stats()["resident_bytes"] == cache.resident_bytes > 0
    # the original recency policy would have kept it and dropped leaf 1
    lru = _attached_cache(eviction="lru")
    _route(lru, 1, 3)
    _route(lru, 2, 3)
    _route(lru, 0, 1)
    assert lru.evictions == 1
    assert 0 in lru._slabs and 1 not in lru._slabs


def test_cache_cost_hint_ema_ignores_bad_samples():
    cache = HotLeafCache(8)
    cache.note_engine_cost(None)
    cache.note_engine_cost(-2.0)
    assert cache.cost_hint_ms is None
    cache.note_engine_cost(4.0)
    cache.note_engine_cost(8.0)  # EMA fold, not overwrite: 4 + 0.25 * 4
    assert cache.cost_hint_ms == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# persistence: index-once / serve-many
# ---------------------------------------------------------------------------


def test_index_persist_roundtrip(tmp_path, corpus):
    vecs_np, tree, mesh, index, q_np = corpus
    d = str(tmp_path / "idx")
    assert not persist.has_index(d)
    persist.save_index(d, index, tree, extra={"images": 375,
                                              "desc_per_image": DPI})
    assert persist.has_index(d)
    r_index, r_tree, meta = persist.load_index(d, mesh)
    assert meta["images"] == 375 and meta["n_leaves"] == index.n_leaves
    assert meta["fanouts"] == [8, 4]
    for a, b in (
        (index.vecs, r_index.vecs), (index.ids, r_index.ids),
        (index.leaves, r_index.leaves), (index.offsets, r_index.offsets),
        (index.n_valid, r_index.n_valid),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(tree.levels, r_tree.levels):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the restored index serves identical results
    res_a = batch_search(index, tree, jnp.asarray(q_np[:16]), k=3, mesh=mesh)
    res_b = batch_search(r_index, r_tree, jnp.asarray(q_np[:16]), k=3,
                         mesh=mesh)
    np.testing.assert_array_equal(np.array(res_a.ids), np.array(res_b.ids))
    # corpus store round-trip
    persist.save_corpus(d, vecs_np, block_rows=1024)
    st = persist.load_corpus(d)
    rows = np.array([0, 1023, 1024, 2999])
    np.testing.assert_array_equal(st.read_rows(rows), vecs_np[rows])


def test_load_or_build_prefers_checkpoint(tmp_path, corpus):
    vecs_np, tree, mesh, index, q_np = corpus
    d = str(tmp_path / "idx2")
    calls = []

    def build_fn():
        calls.append(1)
        return index, tree, {"images": 375}

    s1, meta1 = SearchSession.load_or_build(
        d, build_fn=build_fn, mesh=mesh, k=3, buckets=(32,))
    assert calls == [1] and meta1["restored"] is False
    s2, meta2 = SearchSession.load_or_build(
        d, build_fn=build_fn, mesh=mesh, k=3, buckets=(32,))
    assert calls == [1] and meta2["restored"] is True  # no rebuild
    assert meta2["images"] == 375
    s3, meta3 = SearchSession.load_or_build(
        d, build_fn=build_fn, mesh=mesh, rebuild=True, k=3, buckets=(32,))
    assert calls == [1, 1] and meta3["restored"] is False
