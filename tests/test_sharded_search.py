"""Sharded scatter-gather search: ShardPlan derivation/persistence, and the
acceptance invariant — sharded results bit-identical to unsharded (ids AND
distances) at shard counts 1-4, both layouts, probes >= 1, with deletes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.tree import build_tree
from repro.data import synth
from repro.distributed.meshutil import local_mesh, shard_submeshes
from repro.index import Index, ShardedIndex, ShardPlan

DIM = 16
N = 2000


@pytest.fixture(scope="module")
def corpus():
    vecs_np, _ = synth.sample_descriptors(N, DIM, seed=0, n_centers=40)
    tree = build_tree(jnp.asarray(vecs_np), (8, 4), key=jax.random.PRNGKey(1))
    mesh = local_mesh()
    q_np = vecs_np[:48] + np.random.default_rng(2).standard_normal(
        (48, DIM)
    ).astype(np.float32)
    return vecs_np, tree, mesh, q_np


def _grow(corpus, bounds, directory=None):
    vecs_np, tree, mesh, _ = corpus
    idx = Index.create(tree, directory, mesh=mesh)
    for lo, hi in zip((0,) + bounds, bounds + (N,)):
        if hi > lo:
            idx.append(vecs_np[lo:hi])
    idx.commit()
    return idx


# ---------------------------------------------------------------------------
# ShardPlan: derivation, validation, serialization
# ---------------------------------------------------------------------------


def test_round_robin_covers_and_keeps_global_order():
    names = [f"seg_{i:06d}" for i in range(1, 8)]
    p = ShardPlan.round_robin(names, 3)
    assert p.covers(names)
    assert p.assignment[0] == (names[0], names[3], names[6])
    for shard in p.assignment:  # global append order within every shard
        assert list(shard) == sorted(shard)


def test_balanced_spreads_sizes_and_keeps_global_order():
    names = [f"seg_{i:06d}" for i in range(1, 6)]
    sizes = [100, 100, 100, 100, 400]  # one giant segment
    p = ShardPlan.balanced(names, sizes, 2)
    assert p.covers(names)
    by_name = dict(zip(names, sizes))
    loads = [sum(by_name[n] for n in shard) for shard in p.assignment]
    assert max(loads) == 400 and min(loads) == 400  # LPT: 400 vs 4x100
    for shard in p.assignment:
        assert list(shard) == sorted(shard)


def test_shardplan_validation():
    with pytest.raises(ValueError, match="must be >= 1"):
        ShardPlan.round_robin(["a"], 0)
    with pytest.raises(ValueError, match="unknown shard strategy"):
        ShardPlan(n_shards=1, strategy="hash", assignment=(("a",),))
    with pytest.raises(ValueError, match="twice"):
        ShardPlan.explicit([["a", "b"], ["b"]])
    with pytest.raises(ValueError, match="sizes"):
        ShardPlan.balanced(["a", "b"], [1], 2)
    p = ShardPlan.explicit([["a"], ["b"]])
    assert p.shard_of("b") == 1
    with pytest.raises(KeyError):
        p.shard_of("c")
    assert not p.covers(["a", "b", "c"])


def test_shardplan_json_roundtrip():
    p = ShardPlan.round_robin([f"seg_{i:06d}" for i in range(1, 5)], 3)
    assert ShardPlan.from_json(p.to_json()) == p


def test_explicit_plan_cannot_rederive(corpus):
    idx = _grow(corpus, (1000,))
    p = ShardPlan.explicit([[s.name] for s in idx.segments])
    with pytest.raises(ValueError, match="cannot derive"):
        p.rederived(idx)


# ---------------------------------------------------------------------------
# the acceptance invariant: sharded == unsharded, bit for bit
# ---------------------------------------------------------------------------


@settings(max_examples=6)
@given(
    n_segments=st.integers(1, 4),
    n_shards=st.integers(1, 4),
    layout=st.sampled_from(["point_major", "query_routed"]),
    strategy=st.sampled_from(["round_robin", "balanced"]),
    probes=st.integers(1, 2),
    with_deletes=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_sharded_search_bit_identical_property(
    corpus, n_segments, n_shards, layout, strategy, probes, with_deletes, seed
):
    vecs_np, tree, mesh, q_np = corpus
    rng = np.random.default_rng(seed)
    # segment boundaries on a 500-row grid: bounded compile diversity
    cuts = rng.choice([500, 1000, 1500], size=n_segments - 1, replace=False)
    idx = _grow(corpus, tuple(sorted(int(c) for c in cuts)))
    if with_deletes:
        idx.delete(rng.choice(N, size=25, replace=False))
    ref = idx.search(q_np, k=5, layout=layout, probes=probes, q_cap=512)
    sharded = ShardedIndex(idx, n_shards=n_shards, strategy=strategy)
    res = sharded.search(q_np, k=5, layout=layout, probes=probes, q_cap=512)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(res.dists),
                                  np.asarray(ref.dists))
    assert float(res.pairs) == float(ref.pairs)
    assert int(res.q_cap_overflow) == int(ref.q_cap_overflow)


def test_sharded_search_empty_index_and_empty_shards(corpus):
    vecs_np, tree, mesh, q_np = corpus
    empty = Index.create(tree, None, mesh=mesh)
    res = ShardedIndex(empty, n_shards=2).search(q_np[:4], k=3)
    assert (np.asarray(res.ids) == -1).all()
    assert np.isinf(np.asarray(res.dists)).all()
    # more shards than segments: the empty scatter legs contribute nothing
    idx = _grow(corpus, (1000,))
    ref = idx.search(q_np, k=5, q_cap=512)
    res = ShardedIndex(idx, n_shards=4).search(q_np, k=5, q_cap=512)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))


def test_sharded_index_rejects_stale_plan(corpus):
    idx = _grow(corpus, (1000,))
    plan = ShardPlan.for_index(idx, 2)
    idx.append(corpus[0][:500], ids=np.arange(9000, 9500))
    with pytest.raises(ValueError, match="does not cover"):
        ShardedIndex(idx, plan=plan)
    assert ShardedIndex(idx, plan=plan.rederived(idx)).n_shards == 2


# ---------------------------------------------------------------------------
# manifest persistence
# ---------------------------------------------------------------------------


def test_shard_plan_persists_and_follows_lifecycle(corpus, tmp_path):
    vecs_np, tree, mesh, _ = corpus
    d = str(tmp_path / "idx")
    idx = _grow(corpus, (1000,), directory=d)
    sharded = ShardedIndex(idx, n_shards=2, strategy="balanced")
    sharded.persist_plan()
    idx.commit()
    reopened = Index.open(d, mesh=mesh)
    assert reopened.shard_plan == sharded.plan
    # an append + commit re-derives the same strategy over the new set
    reopened.append(vecs_np[:500], ids=np.arange(7000, 7500))
    reopened.commit()
    assert reopened.shard_plan.strategy == "balanced"
    assert reopened.shard_plan.covers([s.name for s in reopened.segments])
    # compaction folds to one segment; the plan follows
    reopened.compact()
    assert reopened.shard_plan.covers([s.name for s in reopened.segments])
    again = Index.open(d, mesh=mesh)
    assert again.shard_plan == reopened.shard_plan
    # explicit plans cannot follow a changed segment set: dropped
    again.set_shard_plan(
        ShardPlan.explicit([[s.name] for s in again.segments])
    )
    again.commit()
    again.append(vecs_np[:500], ids=np.arange(8000, 8500))
    again.commit()
    assert again.shard_plan is None


def test_set_shard_plan_rejects_non_covering(corpus):
    idx = _grow(corpus, (1000,))
    with pytest.raises(ValueError, match="does not cover"):
        idx.set_shard_plan(ShardPlan.explicit([["seg_999999"]]))


# ---------------------------------------------------------------------------
# serving: ShardedSearchSession above the scatter
# ---------------------------------------------------------------------------


def test_sharded_session_matches_unsharded_session(corpus):
    from repro.serving import SearchSession, ShardedSearchSession

    vecs_np, tree, mesh, q_np = corpus
    idx = _grow(corpus, (500, 1500))
    ref = SearchSession(idx, k=5, layout="point_major", probes=2,
                        buckets=(32, 96))
    ref.warmup()
    for n_shards in (1, 2, 3):
        s = ShardedSearchSession(idx, shards=n_shards, k=5,
                                 layout="point_major", probes=2,
                                 buckets=(32, 96))
        s.warmup()
        assert s.recompiles() == len(s.buckets) * min(n_shards, 3)
        for n in (1, 31, 48):
            ids, dists = s.search(q_np[:n])
            ref_ids, ref_dists = ref.search(q_np[:n])
            np.testing.assert_array_equal(ids, ref_ids)
            np.testing.assert_array_equal(dists, ref_dists)
        assert s.steady_state_recompiles() == 0


def test_sharded_session_refresh_after_delete(corpus):
    from repro.serving import ShardedSearchSession

    vecs_np, tree, mesh, q_np = corpus
    idx = _grow(corpus, (1000,))
    s = ShardedSearchSession(idx, shards=2, k=3, buckets=(32,),
                             cache_leaves=tree.n_leaves, cache_admit_after=1)
    s.warmup()
    q = q_np[:8]
    s.search(q)  # admit + memoise (pre-scatter cache)
    hit = s.cache.try_serve(q, 3)
    assert hit is not None
    victim = int(hit[0][0, 0])
    idx.delete([victim])
    s.refresh()
    s.warmup()
    assert s.cache.try_serve(q, 3) is None  # stale slabs dropped
    ids, _ = s.search(q)
    assert victim not in ids
    assert s.steady_state_recompiles() == 0


def test_sharded_session_micro_batcher_and_cache(corpus):
    from repro.serving import MicroBatcher, ShardedSearchSession, \
        TraceLoadGenerator

    vecs_np, tree, mesh, q_np = corpus
    idx = _grow(corpus, (1000,))
    s = ShardedSearchSession(idx, shards=2, k=5, buckets=(64, 128),
                             cache_leaves=64, cache_admit_after=1)
    s.warmup()
    gen = TraceLoadGenerator(vecs_np, 20, seed=3)
    reqs = gen.from_trace(60, N // 20, skew="zipf", rate=400.0)
    done = MicroBatcher(s, max_wait_ms=4.0, max_queue=1024).run(reqs)
    assert s.metrics.requests == 60
    assert s.steady_state_recompiles() == 0
    # a cache-served repeat agrees with the engine's scatter-gather answer
    served = next(c for c in done if c.source == "engine")
    q = gen.requests([served.image_id], [0.0])[0].queries
    if s.cache.try_serve(q, s.k) is not None:
        c_ids, c_d = s.cache.try_serve(q, s.k)
        e_ids, e_d = s.search(q)
        np.testing.assert_array_equal(c_ids, e_ids)
        # ids agree exactly; distances to f32 rounding (the cache contract,
        # same tolerance as tests/test_serving.py)
        np.testing.assert_allclose(c_d, e_d, rtol=1e-3, atol=0.5)


def test_sharded_session_from_persisted_plan(corpus, tmp_path):
    from repro.serving import ShardedSearchSession

    vecs_np, tree, mesh, q_np = corpus
    d = str(tmp_path / "idx")
    idx = _grow(corpus, (1000,), directory=d)
    idx.set_shard_plan(ShardPlan.for_index(idx, 2))
    idx.commit()
    s = ShardedSearchSession(Index.open(d, mesh=mesh), k=3, buckets=(32,))
    assert s.n_shards == 2
    with pytest.raises(ValueError, match="needs shards"):
        ShardedSearchSession(_grow(corpus, (1000,)), k=3, buckets=(32,))


def test_shard_submeshes_fallback_is_shared_mesh():
    mesh = local_mesh()
    subs = shard_submeshes(mesh, 3)
    assert len(subs) == 3
    if len(jax.devices()) == 1:  # sequential-but-isolated fallback
        assert all(m is mesh for m in subs)
    with pytest.raises(ValueError):
        shard_submeshes(mesh, 0)
