"""Deadline-aware SLO scheduling: policy validation, multi-tenant trace
generation (determinism, class mix, burstiness bounds), EDF vs FIFO
bit-identity + class ordering, admission control, per-class metrics, and
the closed-loop ladder tuner."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (
    CalibrationStore,
    PlanShapes,
    bucket_ladder,
    fitted_component,
    plan as make_plan,
)
from repro.core.index_build import build_index
from repro.core.tree import build_tree
from repro.data import synth
from repro.distributed.meshutil import local_mesh
from repro.serving import (
    MicroBatcher,
    SearchSession,
    SLOPolicy,
    TenantClass,
    TraceLoadGenerator,
    default_tenant_mix,
    tune_ladder,
)
from repro.serving.metrics import ServingMetrics
from repro.serving.slo import (
    DEFAULT_DEADLINES_MS,
    PRIORITY_CLASSES,
    class_rank,
    slab_scale_cap,
)

DIM = 24
DPI = 8


@pytest.fixture(scope="module")
def corpus():
    vecs_np, _ = synth.sample_descriptors(3000, DIM, seed=0, n_centers=50)
    vecs = jnp.asarray(vecs_np)
    tree = build_tree(vecs, (8, 4), key=jax.random.PRNGKey(1))
    mesh = local_mesh()
    index = build_index(vecs, tree, mesh, wire_dtype=jnp.float32)
    return vecs_np, tree, mesh, index


def _mixed_burst(vecs_np, n_each: int):
    """``3 * n_each`` requests, all at t=0, classes interleaved by rid."""
    gen = TraceLoadGenerator(vecs_np, DPI, seed=5)
    reqs = gen.requests(np.arange(3 * n_each) % 20, np.zeros(3 * n_each))
    for i, r in enumerate(reqs):
        r.priority = PRIORITY_CLASSES[i % 3]
    return reqs


# ---------------------------------------------------------------------------
# policy: ranks, validation, derived budgets, fitted shed depth
# ---------------------------------------------------------------------------


def test_class_rank_order_and_validation():
    assert class_rank("interactive") < class_rank("standard")
    assert class_rank("standard") < class_rank("batch")
    with pytest.raises(ValueError, match="unknown priority"):
        class_rank("bulk")


def test_slo_policy_validation_and_budgets():
    p = SLOPolicy.default(base_max_wait_ms=8.0)
    for c in PRIORITY_CLASSES:
        assert p.deadlines_ms[c] == DEFAULT_DEADLINES_MS[c]
        assert p.deadline_s(c) == pytest.approx(p.deadlines_ms[c] / 1e3)
    # interactive coalesces briefly, batch coalesces long
    assert (p.max_wait_ms["interactive"] < p.max_wait_ms["standard"]
            < p.max_wait_ms["batch"])
    assert p.max_wait_ms["standard"] == 8.0
    with pytest.raises(ValueError, match="on_overload"):
        SLOPolicy.default(on_overload="panic")
    with pytest.raises(ValueError, match="missing classes"):
        SLOPolicy(deadlines_ms={"interactive": 1.0},
                  max_wait_ms=dict.fromkeys(PRIORITY_CLASSES, 1.0))


def test_policy_for_session_derives_shed_depth_from_fitted_cost():
    class _Session:
        def __init__(self, ms):
            self._ms = ms

        def predicted_ms_per_image(self):
            return self._ms

    # 2000 ms batch deadline / 10 ms per image -> depth 200
    p = SLOPolicy.for_session(_Session(10.0))
    assert p.shed_depth == 200
    # unpriceable session -> shedding disabled, not guessed
    assert SLOPolicy.for_session(_Session(None)).shed_depth is None
    # clamped to [4, max_depth]
    assert SLOPolicy.for_session(_Session(10_000.0)).shed_depth == 4
    assert SLOPolicy.for_session(_Session(0.001), max_depth=64).shed_depth == 64
    # an explicit depth wins over the derivation
    assert SLOPolicy.for_session(_Session(10.0), shed_depth=7).shed_depth == 7


# ---------------------------------------------------------------------------
# multi-tenant traces: determinism, mix, burstiness
# ---------------------------------------------------------------------------


def test_tenant_class_validation():
    with pytest.raises(ValueError, match="unknown priority"):
        TenantClass("bulk", 10, rate=1.0)
    with pytest.raises(ValueError, match="burst_factor"):
        TenantClass("batch", 10, rate=1.0, burst_factor=0.5)
    with pytest.raises(ValueError, match="rate"):
        TenantClass("batch", 10, rate=0.0)


def test_tenant_class_burstiness_bounds():
    b = TenantClass("batch", 400, rate=100.0, burst_factor=5.0,
                    burst_period_s=1.0)
    arr = b.arrivals(np.random.default_rng(0))
    assert (np.diff(arr) >= 0).all()
    # every arrival lands in the first 1/burst_factor of its window
    assert (np.mod(arr, 1.0) <= 1.0 / 5.0 + 1e-9).all()
    # the mean offered rate is unchanged by bursting (same on-clock mass)
    steady = TenantClass("standard", 400, rate=100.0)
    s_arr = steady.arrivals(np.random.default_rng(0))
    assert arr[-1] == pytest.approx(s_arr[-1], rel=0.35)
    assert s_arr[-1] == pytest.approx(400 / 100.0, rel=0.3)


def test_multi_tenant_trace_deterministic_and_mixed(corpus):
    vecs_np, tree, mesh, index = corpus
    gen = TraceLoadGenerator(vecs_np, DPI, seed=5)
    classes = default_tenant_mix(120, rate=100.0)
    assert sum(tc.n_requests for tc in classes) == 120
    a = gen.multi_tenant(classes, 50, seed=9)
    b = gen.multi_tenant(classes, 50, seed=9)
    assert [(r.rid, r.image_id, r.arrival, r.priority) for r in a] == \
           [(r.rid, r.image_id, r.arrival, r.priority) for r in b]
    c = gen.multi_tenant(classes, 50, seed=10)
    assert [(r.image_id, r.arrival) for r in a] != \
           [(r.image_id, r.arrival) for r in c]
    # merged stream is arrival-ordered with dense rids
    assert [r.rid for r in a] == list(range(120))
    assert all(x.arrival <= y.arrival for x, y in zip(a, a[1:]))
    # the class mix survives the merge exactly
    got = {p: sum(1 for r in a if r.priority == p) for p in PRIORITY_CLASSES}
    want = {tc.priority: tc.n_requests for tc in classes}
    assert got == want
    # query vectors come from the shared per-image generator (cache-warm
    # repeats are the same photo)
    for r in a[:5]:
        np.testing.assert_array_equal(r.queries, gen.query_image(r.image_id))


# ---------------------------------------------------------------------------
# EDF vs FIFO: bit-identical results, deadline-aware ordering
# ---------------------------------------------------------------------------


def test_edf_and_fifo_return_bit_identical_results(corpus):
    vecs_np, tree, mesh, index = corpus
    by_sched = {}
    for sched in ("fifo", "edf"):
        s = SearchSession(index, tree, mesh, k=3, layout="point_major",
                          buckets=(64,))
        s.warmup()
        done = MicroBatcher(s, max_wait_ms=5.0, max_queue=256,
                            scheduler=sched).run(_mixed_burst(vecs_np, 12))
        assert s.metrics.requests == 36 and s.metrics.shed == 0
        by_sched[sched] = {c.rid: c for c in done}
    assert set(by_sched["fifo"]) == set(by_sched["edf"])
    for rid, f in by_sched["fifo"].items():
        e = by_sched["edf"][rid]
        np.testing.assert_array_equal(f.ids, e.ids)
        np.testing.assert_array_equal(f.dists, e.dists)


def test_edf_dispatches_interactive_before_batch(corpus):
    vecs_np, tree, mesh, index = corpus
    s = SearchSession(index, tree, mesh, k=3, layout="point_major",
                      buckets=(64,))
    s.warmup()
    done = MicroBatcher(s, max_wait_ms=5.0, max_queue=256,
                        scheduler="edf").run(_mixed_burst(vecs_np, 12))
    finish = {p: [] for p in PRIORITY_CLASSES}
    for c in done:
        finish[c.priority].append(c.finish)
    # a concurrent burst dispatches in class order: every interactive
    # request completes no later than the last batch request, and the
    # class medians are strictly ordered
    assert max(finish["interactive"]) <= max(finish["batch"])
    assert np.median(finish["interactive"]) < np.median(finish["batch"])
    m = s.metrics
    int_p50 = m.per_class["interactive"].latency.percentile(50)
    bat_p50 = m.per_class["batch"].latency.percentile(50)
    assert int_p50 < bat_p50
    # completions carry the wait/compute split and it sums to latency
    for c in done:
        assert c.latency_ms == pytest.approx(c.wait_ms + c.compute_ms,
                                             rel=1e-6, abs=1e-6)


def test_edf_admission_control_sheds_only_batch(corpus):
    vecs_np, tree, mesh, index = corpus
    gen = TraceLoadGenerator(vecs_np, DPI, seed=5)
    reqs = gen.requests(np.arange(12) % 20, np.zeros(12))
    for r in reqs[:10]:
        r.priority = "batch"
    for r in reqs[10:]:
        r.priority = "interactive"
    policy = SLOPolicy.default(shed_depth=2, on_overload="shed")
    s = SearchSession(index, tree, mesh, k=3, layout="point_major",
                      buckets=(64,))
    s.warmup()
    done = MicroBatcher(s, max_wait_ms=5.0, max_queue=256, scheduler="edf",
                        policy=policy).run(reqs)
    shed = [c for c in done if c.source == "shed"]
    assert len(shed) == 8 and s.metrics.shed == 8
    assert all(c.priority == "batch" and c.ids is None for c in shed)
    # interactive arrivals are admitted past the shed depth
    assert s.metrics.requests == 4
    assert s.metrics.per_class["interactive"].completed == 2
    # shed batch work counts against the batch class's SLO attainment
    assert s.metrics.per_class["batch"].slo_attainment < 1.0


def test_edf_admission_control_downgrade_keeps_requests(corpus):
    vecs_np, tree, mesh, index = corpus
    gen = TraceLoadGenerator(vecs_np, DPI, seed=5)
    reqs = gen.requests(np.arange(12) % 20, np.zeros(12))
    for r in reqs:
        r.priority = "batch"
    policy = SLOPolicy.default(shed_depth=2, on_overload="downgrade")
    s = SearchSession(index, tree, mesh, k=3, layout="point_major",
                      buckets=(64,))
    s.warmup()
    done = MicroBatcher(s, max_wait_ms=5.0, max_queue=256, scheduler="edf",
                        policy=policy).run(reqs)
    assert s.metrics.shed == 0 and s.metrics.downgraded == 10
    assert s.metrics.requests == 12
    assert all(c.source in ("engine", "cache") for c in done)


def test_unknown_scheduler_rejected(corpus):
    vecs_np, tree, mesh, index = corpus
    s = SearchSession(index, tree, mesh, k=3, layout="point_major",
                      buckets=(64,))
    with pytest.raises(ValueError, match="unknown scheduler"):
        MicroBatcher(s, scheduler="lifo")


# ---------------------------------------------------------------------------
# per-class metrics
# ---------------------------------------------------------------------------


def test_metrics_per_class_attainment_and_breakdown():
    m = ServingMetrics()
    m.observe_latency("interactive", wait_ms=10.0, compute_ms=20.0,
                      deadline_ms=50.0)
    m.observe_latency("interactive", wait_ms=100.0, compute_ms=20.0,
                      deadline_ms=50.0)
    m.observe_drop("interactive", "shed")
    m.observe_drop("standard", "rejected")
    cm = m.per_class["interactive"]
    assert cm.completed == 2 and cm.attained == 1 and cm.shed == 1
    assert cm.slo_attainment == pytest.approx(1 / 3)
    assert m.per_class["standard"].rejected == 1
    assert m.shed == 1 and m.rejected == 1
    assert len(m.wait) == 2 and len(m.compute) == 2
    d = m.to_dict()
    assert d["per_class"]["interactive"]["slo_attainment"] == \
        pytest.approx(1 / 3)
    assert d["wait"]["count"] == 2 and d["compute"]["count"] == 2
    with pytest.raises(ValueError, match="unknown drop"):
        m.observe_drop("batch", "lost")
    # queue-depth percentiles are defined even with no samples
    assert ServingMetrics().queue_summary()["p95"] == 0


# ---------------------------------------------------------------------------
# ladder tuner + slab-scale cap
# ---------------------------------------------------------------------------


def _calibrated_store(rows=65_536, n_leaves=64):
    cal = CalibrationStore()
    for layout in ("point_major", "query_routed"):
        for b in (128, 1024):
            p = make_plan(rows=rows, n_leaves=n_leaves, n_queries=b,
                          n_shards=1, k=10, layout=layout)
            cal.record(p, 2.0, PlanShapes(rows=rows, n_queries=b,
                                          n_shards=1, n_leaves=n_leaves))
    assert fitted_component("auto", cal) is not None
    return cal


def test_tune_ladder_without_fit_keeps_stock_ladder():
    d = tune_ladder(CalibrationStore(), target_p95_ms=100.0, rows=65_536,
                    n_leaves=64, desc_per_image=8, max_batch_rows=1024)
    assert d.decided_by == "default"
    assert d.buckets == bucket_ladder(1024, n_buckets=3)
    assert d.predicted_dispatch_ms is None
    assert d.max_wait_ms == 5.0


def test_tune_ladder_fitted_scales_bucket_with_target():
    cal = _calibrated_store()
    kw = dict(rows=65_536, n_leaves=64, desc_per_image=8,
              max_batch_rows=1024, n_buckets=3)
    generous = tune_ladder(cal, target_p95_ms=1e6, **kw)
    assert generous.decided_by == "fitted"
    assert generous.buckets[-1] == 1024  # everything fits: keep the top
    assert generous.predicted_dispatch_ms > 0
    assert generous.max_wait_ms == 5.0  # ample slack: base budget kept
    tight = tune_ladder(cal, target_p95_ms=1e-3, **kw)
    assert tight.decided_by == "fitted"
    # an unmeetable target degrades to the smallest plannable rung and
    # the coalescing budget floors at 1 ms rather than going negative
    assert tight.buckets[-1] < generous.buckets[-1]
    assert tight.max_wait_ms == 1.0
    # ladders are always real ladders: rungs divide the top rung
    for d in (generous, tight):
        assert all(d.buckets[-1] % r == 0 for r in d.buckets)


def test_slab_scale_cap_bounds():
    assert slab_scale_cap(None, 10.0) == 2.0  # no target: stock cap
    assert slab_scale_cap(100.0, None) == 2.0  # unpriceable: stock cap
    # cheap dispatch: growth allowed up to the stock cap
    assert slab_scale_cap(100.0, 10.0) == 2.0
    # dispatch already eats the budget: growth clamped to 1 (never shrink)
    assert slab_scale_cap(100.0, 100.0) == 1.0
    # in between: cap = target * dispatch_fraction / predicted
    assert slab_scale_cap(100.0, 30.0) == pytest.approx(100.0 * 0.5 / 30.0)
