"""DescriptorStore: on-disk round-trip, virtual-store equivalence, and
non-divisible tail-block handling (the HDFS-chunk analog, paper §2.3)."""

import numpy as np
import pytest

from repro.data.store import DescriptorStore, VirtualStore


def test_create_read_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    vecs = rng.standard_normal((300, 16)).astype(np.float32)
    ids = np.arange(1000, 1300, dtype=np.int64)
    st = DescriptorStore.create(str(tmp_path / "s"), vecs, block_rows=128,
                                ids=ids)
    assert (st.n_rows, st.dim, st.block_rows, st.n_blocks) == (300, 16, 128, 3)
    # reopening reads the manifest, not the creation args
    st2 = DescriptorStore(str(tmp_path / "s"))
    got_v = np.concatenate([b.vecs for b in st2.blocks()])
    got_i = np.concatenate([b.ids for b in st2.blocks()])
    np.testing.assert_array_equal(got_v, vecs)
    np.testing.assert_array_equal(got_i, ids)


def test_non_divisible_tail_block(tmp_path):
    """block_rows that doesn't divide n_rows: the tail block is short, no
    padding rows are invented, and row addressing stays exact."""
    vecs = np.arange(250 * 4, dtype=np.float32).reshape(250, 4)
    st = DescriptorStore.create(str(tmp_path / "s"), vecs, block_rows=64)
    assert st.n_blocks == 4
    sizes = [st.read_block(b).vecs.shape[0] for b in range(4)]
    assert sizes == [64, 64, 64, 58]
    np.testing.assert_array_equal(
        np.concatenate([st.read_block(b).vecs for b in range(4)]), vecs
    )
    # read_rows across the tail boundary
    rows = np.array([0, 63, 64, 191, 192, 249])
    np.testing.assert_array_equal(st.read_rows(rows), vecs[rows])


def test_virtual_store_equivalence(tmp_path):
    """Materialising a VirtualStore into an on-disk DescriptorStore yields
    the identical stream: same blocks, same rows, same read_rows gather."""
    vst = VirtualStore(1000, 8, block_rows=256, seed=7)
    assert vst.n_blocks == 4
    all_vecs = np.concatenate([b.vecs for b in vst.blocks()])
    all_ids = np.concatenate([b.ids for b in vst.blocks()])
    np.testing.assert_array_equal(all_ids, np.arange(1000))
    dst = DescriptorStore.create(str(tmp_path / "d"), all_vecs,
                                 block_rows=256, ids=all_ids)
    for b in range(4):
        vb, db = vst.read_block(b), dst.read_block(b)
        np.testing.assert_array_equal(vb.vecs, db.vecs)
        np.testing.assert_array_equal(vb.ids, db.ids)
    rows = np.array([5, 255, 256, 511, 999, 3])
    np.testing.assert_array_equal(vst.read_rows(rows), dst.read_rows(rows))
    # virtual blocks are a pure function of (seed, block): re-read matches
    np.testing.assert_array_equal(vst.read_block(2).vecs,
                                  VirtualStore(1000, 8, block_rows=256,
                                               seed=7).read_block(2).vecs)


def test_read_rows_bounds(tmp_path):
    vecs = np.zeros((10, 4), np.float32)
    st = DescriptorStore.create(str(tmp_path / "s"), vecs, block_rows=4)
    with pytest.raises(IndexError):
        st.read_rows(np.array([10]))
    with pytest.raises(IndexError):
        st.read_rows(np.array([-1]))
    assert st.read_rows(np.array([], dtype=np.int64)).shape == (0, 4)
    assert st.read_rows([]).shape == (0, 4)  # empty python list too
    with pytest.raises(ValueError):
        st.read_rows(np.zeros((2, 2), np.int64))  # 2-D selections rejected


def test_read_rows_out_of_order_duplicates_and_tail(tmp_path):
    """The contract segment search and trace replay rely on: out-of-order
    and duplicated selections gather positionally (out[i] == vecs[rows[i]])
    even when the selection criss-crosses the final partial block."""
    vecs = np.arange(250 * 4, dtype=np.float32).reshape(250, 4)
    st = DescriptorStore.create(str(tmp_path / "s"), vecs, block_rows=64)
    rows = np.array([249, 0, 192, 63, 249, 64, 0, 191, 248])  # dups + tail
    np.testing.assert_array_equal(st.read_rows(rows), vecs[rows])
    # python-list and int32 selections behave identically
    np.testing.assert_array_equal(st.read_rows(list(rows)), vecs[rows])
    np.testing.assert_array_equal(
        st.read_rows(rows.astype(np.int32)), vecs[rows]
    )
    # a scalar row id is promoted to a single-row gather
    np.testing.assert_array_equal(st.read_rows(249), vecs[[249]])
    # virtual stores share the same gather contract
    vst = VirtualStore(250, 4, block_rows=64, seed=3)
    all_vecs = np.concatenate([b.vecs for b in vst.blocks()])
    np.testing.assert_array_equal(vst.read_rows(rows), all_vecs[rows])
