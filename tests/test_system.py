"""End-to-end behaviour of the paper's system: the full workflow (store ->
tree -> distributed index -> batch search -> image-level quality), fault
injection, and the per-arch reduced-config smoke tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, REGISTRY


# ---------------------------------------------------------------------------
# the paper's workflow end-to-end (Fig 4 protocol, scaled down)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def workflow():
    from repro.core.index_build import build_index
    from repro.core.tree import build_tree
    from repro.data import synth
    from repro.distributed.meshutil import local_mesh

    mesh = local_mesh()
    n_images, dpi, dim = 400, 24, 32
    vecs_np, img_ids = synth.sample_images(n_images, dpi, dim, seed=0)
    vecs = jnp.asarray(vecs_np)
    tree = build_tree(vecs, (8, 8), key=jax.random.PRNGKey(1))
    index = build_index(vecs, tree, mesh, wire_dtype=jnp.float32)
    return mesh, vecs_np, img_ids, tree, index, n_images


def test_copydays_quality_protocol(workflow):
    """Distorted queries find their original image at rank 1 (paper: ~82%
    averaged over variants; mild variants should be near-perfect, strong
    ones lower but nonzero)."""
    from repro.core.search import batch_search
    from repro.data.copydays import VARIANTS, make_copydays, vote_images

    mesh, vecs_np, img_ids, tree, index, n_images = workflow
    rng = np.random.default_rng(3)
    originals = rng.choice(n_images, 40, replace=False)
    rows = np.isin(img_ids, originals)
    cd = make_copydays(vecs_np[rows], img_ids[rows], seed=4)
    res = batch_search(
        index, tree, jnp.asarray(cd.query_vecs), k=10, mesh=mesh, q_cap=1024
    )
    assert int(res.q_cap_overflow) == 0
    per_variant, avg = vote_images(
        np.array(res.ids), img_ids, cd.query_img, cd.query_variant, len(VARIANTS)
    )
    # mild variants near-perfect; average well above chance
    assert per_variant[0] >= 0.9, per_variant
    assert avg >= 0.5, (per_variant, avg)


def test_search_quality_stable_with_more_distractors(workflow):
    """Paper Fig 4: 20M -> 100M distractors barely degrades recall."""
    from repro.core.index_build import build_index
    from repro.core.search import batch_search
    from repro.core.tree import build_tree
    from repro.data import synth

    mesh, vecs_np, img_ids, _, _, n_images = workflow
    extra, _ = synth.sample_descriptors(3 * len(vecs_np), 32, seed=9,
                                        n_centers=256)
    recalls = []
    for corpus in (vecs_np, np.concatenate([vecs_np, extra])):
        vecs = jnp.asarray(corpus)
        tree = build_tree(vecs, (8, 8), key=jax.random.PRNGKey(1))
        index = build_index(vecs, tree, mesh, wire_dtype=jnp.float32)
        q = jnp.asarray(
            vecs_np[:300]
            + np.random.default_rng(5).standard_normal((300, 32)).astype(np.float32) * 2
        )
        res = batch_search(index, tree, q, k=1, mesh=mesh, q_cap=2048)
        recalls.append(float((np.array(res.ids[:, 0]) == np.arange(300)).mean()))
    assert recalls[0] >= 0.85
    assert recalls[1] >= recalls[0] - 0.12, recalls


def test_streaming_index_with_failures_matches_clean_run():
    """launch/index.py semantics: injected failures + retries produce an
    index identical to the failure-free run (deterministic re-execution)."""
    import jax.numpy as jnp

    from repro.core.index_build import build_index
    from repro.core.tree import build_tree
    from repro.data.store import VirtualStore
    from repro.distributed.failure import FailureInjector
    from repro.distributed.meshutil import local_mesh
    from repro.distributed.wavescheduler import WaveScheduler

    mesh = local_mesh()
    store = VirtualStore(20_000, 16, block_rows=5_000, seed=0, n_centers=64)
    tree = build_tree(
        jnp.asarray(store.sample_for_tree(4096)), (4, 8),
        key=jax.random.PRNGKey(0),
    )

    def wave_fn(b):
        blk = store.read_block(b)
        idx = build_index(
            jnp.asarray(blk.vecs), tree, mesh,
            ids=jnp.asarray(blk.ids.astype(np.int32)),
            wire_dtype=jnp.float32,
        )
        return np.sort(np.array(idx.ids)[np.array(idx.ids) >= 0])

    clean = WaveScheduler(wave_fn).run(range(store.n_blocks))
    faulty = WaveScheduler(
        wave_fn,
        failure_injector=FailureInjector(fail_at=[(1, 0), (2, 0)]),
        max_retries=1,
    ).run(range(store.n_blocks))
    for a, b in zip(clean.state, faulty.state):
        np.testing.assert_array_equal(a, b)
    total = np.concatenate(clean.state)
    assert len(total) == store.n_rows
    assert len(np.unique(total)) == store.n_rows


# ---------------------------------------------------------------------------
# per-arch smoke tests (reduced configs, one train/serve step each)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ASSIGNED + ["sift100m"])
def test_arch_smoke(arch):
    metrics = REGISTRY[arch].smoke()
    assert metrics, f"{arch} smoke returned no metrics"


def test_all_assigned_archs_have_four_shapes():
    for arch in ASSIGNED:
        cells = REGISTRY[arch].cells
        assert len(cells) == 4, (arch, sorted(cells))


def test_full_config_param_counts_match_names():
    """Sanity: the headline parameter counts roughly match the arch names."""
    lm = {
        "llama3.2-3b": (2.5e9, 4.5e9),
        "gemma3-4b": (3.0e9, 5.5e9),
        "internlm2-1.8b": (1.4e9, 2.3e9),
        "moonshot-v1-16b-a3b": (1.2e10, 3.2e10),
        "phi3.5-moe-42b-a6.6b": (3.6e10, 4.6e10),
    }
    for arch, (lo, hi) in lm.items():
        n = REGISTRY[arch].config.param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"
    active = REGISTRY["phi3.5-moe-42b-a6.6b"].config.active_param_count()
    assert 5.5e9 <= active <= 8.5e9, active
