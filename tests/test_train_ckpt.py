"""Optimizer, gradient compression, microbatching, checkpointing, waves."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.failure import FailureInjector, InjectedFailure
from repro.distributed.wavescheduler import WaveScheduler, plan_waves
from repro.train import AdamWConfig, adamw_update, init_opt_state, make_train_step
from repro.train.grad_compress import bf16_compress, init_feedback, topk_compress
from repro.train.step import init_train_state


def quad_loss(p, batch):
    r = p["w"] * batch["x"] - batch["y"]
    return jnp.mean(r * r), {"loss": jnp.mean(r * r)}


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray(5.0)}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.1, clip_norm=None)
    batch = {"x": jnp.ones(()), "y": jnp.asarray(2.0)}
    for _ in range(200):
        grads = jax.grad(lambda p: quad_loss(p, batch)[0])(params)
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert abs(float(params["w"]) - 2.0) < 1e-2


def test_grad_clip_bounds_update():
    params = {"w": jnp.asarray(0.0)}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3)
    grads = {"w": jnp.asarray(1e6)}
    _, _, metrics = adamw_update(params, grads, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(1e6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_bf16_error_feedback_conserves_mass(seed):
    g = {"a": jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 1e-3}
    fb = init_feedback(g)
    total = jnp.zeros((64,))
    sent = jnp.zeros((64,))
    for i in range(8):
        comp, fb = bf16_compress(g, fb)
        sent = sent + comp["a"].astype(jnp.float32)
        total = total + g["a"]
    # error feedback: accumulated sent + residual == accumulated true grads
    np.testing.assert_allclose(
        np.array(sent + fb["a"]), np.array(total), rtol=1e-5, atol=1e-6
    )


def test_topk_compression_sparsity_and_feedback():
    g = {"a": jnp.arange(1.0, 101.0)}
    fb = init_feedback(g)
    comp, fb = topk_compress(g, fb, fraction=0.1)
    nz = int((np.array(comp["a"]) != 0).sum())
    assert nz == 10
    np.testing.assert_allclose(
        np.array(comp["a"] + fb["a"]), np.array(g["a"]), rtol=1e-6
    )


def test_microbatch_equals_full_batch():
    cfg = AdamWConfig(lr=1e-2)
    params = {"w": jnp.asarray([1.0, -1.0])}

    def loss(p, b):
        r = b["x"] @ p["w"] - b["y"]
        return jnp.mean(r * r), {"loss": jnp.mean(r * r)}

    x = jax.random.normal(jax.random.PRNGKey(0), (16, 2))
    y = jax.random.normal(jax.random.PRNGKey(1), (16,))
    batch = {"x": x, "y": y}
    s1 = init_train_state(params)
    s2 = init_train_state(params)
    p1, _, m1 = make_train_step(loss, cfg)(params, s1, batch)
    p2, _, m2 = make_train_step(loss, cfg, microbatches=4)(params, s2, batch)
    np.testing.assert_allclose(np.array(p1["w"]), np.array(p2["w"]), rtol=1e-5)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
    for step in (1, 2, 3):
        mgr.save(step, tree)
    assert mgr.all_steps() == [2, 3]  # GC keeps last 2
    restored, manifest = mgr.restore(tree)
    assert manifest["step"] == 3
    np.testing.assert_array_equal(np.array(restored["a"]), np.arange(10.0))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_crc_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.arange(16.0)}
    path = mgr.save(7, tree)
    fname = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(path, fname))
    arr[0] = 999.0
    np.save(os.path.join(path, fname), arr)
    with pytest.raises(IOError, match="crc"):
        mgr.restore(tree)


def test_checkpoint_restore_with_shardings(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.meshutil import local_mesh

    mesh = local_mesh()
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(32.0).reshape(8, 4)}
    mgr.save(1, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = mgr.restore(tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.array(restored["w"]), np.array(tree["w"]))


# ---------------------------------------------------------------------------
# wave scheduler + failure injection
# ---------------------------------------------------------------------------


def test_waves_retry_reproduces_exact_results():
    def wave_fn(w):
        start, size = w
        return np.arange(start, start + size) ** 2

    waves = plan_waves(100, 13)
    clean = WaveScheduler(wave_fn).run(waves)
    injector = FailureInjector(fail_at=[(0, 0), (3, 0), (3, 1)])
    faulty = WaveScheduler(wave_fn, failure_injector=injector, max_retries=2).run(waves)
    assert injector.fired == [(0, 0), (3, 0), (3, 1)]
    assert len([r for r in faulty.records if not r.ok]) == 3
    for a, b in zip(clean.state, faulty.state):
        np.testing.assert_array_equal(a, b)


def test_waves_exhausted_retries_raise():
    injector = FailureInjector(fail_at=[(1, 0), (1, 1)])
    sched = WaveScheduler(lambda w: w, failure_injector=injector, max_retries=1)
    with pytest.raises(InjectedFailure):
        sched.run([1, 2, 3])


def test_wave_checkpoint_resume(tmp_path):
    """Kill the job mid-run; resume completes with identical final state."""
    mgr = CheckpointManager(str(tmp_path))
    calls = []

    def wave_fn(w):
        calls.append(w)
        return w * 2

    def fold(s, r):
        s = s or {"acc": np.zeros(1)}
        return {"acc": s["acc"] + r}

    sched = WaveScheduler(
        wave_fn, fold, checkpoint=mgr, checkpoint_every=2,
        failure_injector=FailureInjector(fail_at=[(5, 0), (5, 1), (5, 2)]),
        max_retries=2,
    )
    with pytest.raises(InjectedFailure):
        sched.run(range(10))
    # resume from the surviving checkpoint
    cursor = sched.resume_cursor()
    assert cursor == 4  # checkpoints at waves 2 and 4
    state = sched.resume_state({"acc": np.zeros(1)})
    sched2 = WaveScheduler(wave_fn, fold, checkpoint=mgr, checkpoint_every=2)
    out = sched2.run(range(10), init_state=state, start_at=cursor)
    assert out.state["acc"][0] == sum(w * 2 for w in range(10))


def test_elastic_replanning():
    w8 = plan_waves(100, 8)
    w32 = plan_waves(100, 32)
    assert sum(s for _, s in w8) == sum(s for _, s in w32) == 100
    assert len(w8) > len(w32)
