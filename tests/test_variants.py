"""Hillclimb-variant correctness: each beyond-baseline optimization must be
numerically equivalent to its baseline (the §Perf wins are free lunches,
not approximations — except where documented)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tfm
from repro.models.module import init_params, shard_ctx


@pytest.fixture(scope="module")
def moe_setup():
    cfg = tfm.TransformerConfig(
        name="m", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
        d_ff=48, vocab_size=64, dtype="float32",
        moe=tfm.MoEConfig(n_experts=4, top_k=2, d_ff=48, capacity_factor=4.0),
    )
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    return cfg, params, toks


def test_routed_moe_matches_global(moe_setup):
    from repro.distributed.meshutil import local_mesh

    cfg, params, toks = moe_setup
    mesh = local_mesh()
    cfg_r = dataclasses.replace(cfg, moe_impl="routed")

    def run(c):
        def f(p, t):
            with shard_ctx(mesh):
                return tfm.forward(p, c, t)[0]

        return jax.jit(f)(params, toks)

    np.testing.assert_allclose(
        np.array(run(cfg)), np.array(run(cfg_r)), atol=2e-4
    )


def test_chunked_attention_matches_full():
    cfg = tfm.TransformerConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=64, dtype="float32", window=6, global_every=2,
    )
    cfg_c = dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=8)
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 64)
    lf, _ = jax.jit(lambda p, t: tfm.forward(p, cfg, t))(params, toks)
    lc, _ = jax.jit(lambda p, t: tfm.forward(p, cfg_c, t))(params, toks)
    np.testing.assert_allclose(np.array(lf), np.array(lc), atol=2e-4)


def test_query_routed_search_matches_point_major():
    from repro.core.index_build import build_index
    from repro.core.search import batch_search
    from repro.core.tree import build_tree
    from repro.distributed.meshutil import local_mesh

    mesh = local_mesh()
    vecs = jax.random.normal(jax.random.PRNGKey(0), (3000, 16)) * 4
    tree = build_tree(vecs, (6, 6), key=jax.random.PRNGKey(1))
    index = build_index(vecs, tree, mesh, wire_dtype=jnp.float32)
    q = vecs[:150] + 0.1
    r1 = batch_search(index, tree, q, k=4, mesh=mesh, q_cap=512)
    r2 = batch_search(index, tree, q, k=4, mesh=mesh, layout="query_routed")
    assert int(r2.q_cap_overflow) == 0
    np.testing.assert_array_equal(np.array(r1.ids), np.array(r2.ids))
    m = np.isfinite(np.array(r1.dists))
    np.testing.assert_allclose(
        np.array(r1.dists)[m], np.array(r2.dists)[m], rtol=1e-3, atol=1.0
    )


def test_head_pad_variant_cells_construct():
    from jax.sharding import AbstractMesh

    from repro.configs import variants

    cell = variants.apply("head_pad", "llama3.2-3b", "train_4k")
    assert cell.kind == "train"
    cell = variants.apply("routed_moe", "phi3.5-moe-42b-a6.6b", "train_4k")
    assert cell.kind == "train"
    cell = variants.apply("query_routed", "sift100m", "search_1m")
    assert cell.kind == "serve"
